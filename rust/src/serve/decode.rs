//! Autoregressive decode serving: prefill/decode split, KV-cache
//! residency, and iteration-level continuous batching.
//!
//! Generation on a weight-stationary IMC system is one **prefill** pass
//! over the full prompt (the existing stage pipeline, emitting the
//! first token) followed by one **decode step** per further token. A
//! decode step re-runs every weight layer on a single-token input, so
//! its cost comes from the `seq1` stage graph of the same design point:
//! the per-request analog compute (`var_ns`, scales with batch
//! occupancy) plus the shared ingress / NoC / NoP overhead (`fixed_ns`,
//! paid once per step).
//!
//! The KV cache holds `2 · causal_layers · dim · kv_precision_bits`
//! bits per cached token. Residency is charged against the global
//! buffer; the overflow spills to the DRAM chiplet through the existing
//! [`crate::dram`] timing model (read latency and energy per step), and
//! the on-chip share is re-read by the causal-attention chiplets over
//! the interposer — a NoP epoch through the shared flow caches, exactly
//! like weight-layer traffic.
//!
//! The engine batches at iteration granularity: requests join a running
//! batch between decode steps (after a sequential prefill pass) and
//! leave it when their `max_new_tokens` are out, so per-step service
//! time tracks the live occupancy. Open-loop arrivals shed beyond
//! `[serve] queue_depth`; closed-loop clients re-issue on completion;
//! the mid-run chiplet-failure scenario sheds the in-flight batch and
//! resumes on a remapped [`DecodeModel`] after the remap latency.
//!
//! Calibration invariants (asserted by tests and the `decode_throughput`
//! bench):
//!
//! * closed-loop concurrency-1 tokens/second equals the reciprocal of
//!   the analytic per-token closed form (same cost helper, so the two
//!   differ only by float accumulation order);
//! * continuous batching at `batch_cap` B beats B sequential
//!   single-request runs whenever the KV cache fits on chip
//!   (`fixed_ns > 0` is amortized over the batch);
//! * fixed seed ⇒ bit-identical reports.

use std::collections::{BTreeSet, VecDeque};

use crate::config::{DecodeConfig, DramConfig, ServeConfig, ServeMode, SiamConfig};
use crate::coordinator::pipeline::stage_dnn;
use crate::coordinator::{FailoverReport, ServeReport, SweepContext};
use crate::dnn::LayerKind;
use crate::mapping::{canonicalize_flows, Flow};
use crate::noc::{EpochCache, Mesh, PacketSim};
use crate::obs::{CacheSnapshot, RunMeta, TraceBuffer};
use crate::serve::stage::StageGraph;
use crate::serve::{percentile, poisson_arrivals};
use crate::util::json::Json;
use anyhow::{anyhow, ensure, Result};

/// `pid` of the decode-serving process in emitted Chrome traces
/// (distinct from the classic serving engine's `pid 1`).
const TRACE_PID_DECODE: u32 = 2;

/// Observer of the decode engine's event stream. All methods default to
/// no-ops; implementations are pure observers — the report is
/// bit-identical with or without a sink attached.
pub trait DecodeSink {
    /// A request entered the waiting queue at `t_ns`.
    fn admitted(&mut self, _t_ns: f64, _req: u32) {}
    /// A request was shed (queue full, or lost to a chiplet failure).
    fn shed(&mut self, _t_ns: f64, _req: u32) {}
    /// A prefill pass ran over `[start_ns, start_ns + dur_ns)`.
    fn prefill(&mut self, _start_ns: f64, _dur_ns: f64, _req: u32) {}
    /// A decode step at occupancy `batch` ran over
    /// `[start_ns, start_ns + dur_ns)`.
    fn step(&mut self, _start_ns: f64, _dur_ns: f64, _batch: usize) {}
    /// Request `req` emitted its `token`-th generated token at `t_ns`.
    fn token(&mut self, _t_ns: f64, _req: u32, _token: usize) {}
    /// A request finished all its tokens.
    fn completed(&mut self, _t_ns: f64, _req: u32, _latency_ns: f64) {}
    /// The failure scenario triggered, shedding `shed` in-flight
    /// requests.
    fn failed(&mut self, _t_ns: f64, _shed: usize) {}
    /// The remapped pipeline came back up.
    fn resumed(&mut self, _t_ns: f64) {}
}

/// A [`DecodeSink`] that ignores every event.
#[derive(Debug, Default)]
pub struct NoopDecodeSink;

impl DecodeSink for NoopDecodeSink {}

/// A [`DecodeSink`] that renders the token-level event stream into a
/// Chrome [`TraceBuffer`] — the implementation behind
/// `siam serve --decode --trace`.
///
/// Track layout: process `pid = 2` ("decode"); `tid 0` carries the
/// request lifecycle (admit / shed / complete / fail / resume
/// instants); `tid 1` carries prefill spans; `tid 2` carries decode-step
/// spans (with the batch occupancy as an argument); `tid 3` carries one
/// instant per generated token. All timestamps are simulated
/// nanoseconds, so two traced runs of the same `(config, seed)` render
/// byte-identical streams.
#[derive(Debug)]
pub struct DecodeTracer {
    buf: TraceBuffer,
}

impl DecodeTracer {
    /// A tracer with the decode process and track names pre-registered.
    pub fn new() -> DecodeTracer {
        let mut buf = TraceBuffer::new();
        buf.process_name(TRACE_PID_DECODE, "decode");
        buf.thread_name(TRACE_PID_DECODE, 0, "requests");
        buf.thread_name(TRACE_PID_DECODE, 1, "prefill");
        buf.thread_name(TRACE_PID_DECODE, 2, "decode-steps");
        buf.thread_name(TRACE_PID_DECODE, 3, "tokens");
        DecodeTracer { buf }
    }

    /// The finished trace buffer.
    pub fn into_buffer(self) -> TraceBuffer {
        self.buf
    }
}

impl Default for DecodeTracer {
    fn default() -> Self {
        DecodeTracer::new()
    }
}

fn req_args(req: u32) -> Json {
    let mut a = Json::obj();
    a.set("req", req as u64);
    a
}

impl DecodeSink for DecodeTracer {
    fn admitted(&mut self, t_ns: f64, req: u32) {
        self.buf.instant("admit", t_ns, TRACE_PID_DECODE, 0, req_args(req));
    }
    fn shed(&mut self, t_ns: f64, req: u32) {
        self.buf.instant("shed", t_ns, TRACE_PID_DECODE, 0, req_args(req));
    }
    fn prefill(&mut self, start_ns: f64, dur_ns: f64, req: u32) {
        self.buf.complete("prefill", start_ns, dur_ns, TRACE_PID_DECODE, 1, req_args(req));
    }
    fn step(&mut self, start_ns: f64, dur_ns: f64, batch: usize) {
        let mut a = Json::obj();
        a.set("batch", batch as u64);
        self.buf.complete("decode-step", start_ns, dur_ns, TRACE_PID_DECODE, 2, a);
    }
    fn token(&mut self, t_ns: f64, req: u32, token: usize) {
        let mut a = req_args(req);
        a.set("token", token as u64);
        self.buf.instant("token", t_ns, TRACE_PID_DECODE, 3, a);
    }
    fn completed(&mut self, t_ns: f64, req: u32, latency_ns: f64) {
        let mut a = req_args(req);
        a.set("latency_ns", latency_ns);
        self.buf.instant("complete", t_ns, TRACE_PID_DECODE, 0, a);
    }
    fn failed(&mut self, t_ns: f64, shed: usize) {
        let mut a = Json::obj();
        a.set("shed", shed as u64);
        self.buf.instant("fail", t_ns, TRACE_PID_DECODE, 0, a);
    }
    fn resumed(&mut self, t_ns: f64) {
        self.buf.instant("resume", t_ns, TRACE_PID_DECODE, 0, Json::Null);
    }
}

/// The deterministic cost of one decode step at a given batch of
/// context lengths, decomposed the way the report accounts it.
#[derive(Debug, Clone, PartialEq)]
pub struct StepCost {
    /// Total step latency, ns (fixed + occupancy · var + spill + NoP).
    pub latency_ns: f64,
    /// Total step dynamic energy, pJ.
    pub energy_pj: f64,
    /// KV bytes the batch holds at this step (before any spill).
    pub residency_bytes: usize,
    /// KV bytes past the global buffer, re-read from DRAM this step.
    pub spill_bytes: usize,
    /// DRAM latency of the spilled re-read, ns.
    pub spill_latency_ns: f64,
    /// DRAM energy of the spilled re-read, pJ.
    pub spill_energy_pj: f64,
    /// Interposer latency of the on-chip KV reads, ns.
    pub kv_nop_ns: f64,
    /// Interposer energy of the on-chip KV reads, pJ.
    pub kv_nop_energy_pj: f64,
}

/// The analytic cost model of autoregressive generation on one design
/// point: prefill cost, per-token decode cost split into fixed and
/// occupancy-scaled shares, KV-cache geometry, and the chiplets whose
/// causal-attention layers read the cache each step.
pub struct DecodeModel {
    prompt_tokens: usize,
    max_new_tokens: usize,
    kv_bytes_per_token: usize,
    kv_capacity_bytes: usize,
    prefill_ns: f64,
    prefill_energy_pj: f64,
    prefill_chunks: usize,
    fixed_ns: f64,
    var_ns: f64,
    token_energy_pj: f64,
    kv_chiplets: Vec<usize>,
    num_chiplets: usize,
    mesh: Mesh,
    nop_clock_ns: f64,
    nop_ebit_pj: f64,
    nop_bits_per_cycle: u64,
    dram: DramConfig,
    /// Per-chiplet busy-ns of one whole-prompt prefill (share-weighted,
    /// already scaled to the chunked prefill duration).
    prefill_busy: Vec<f64>,
    /// Per-chiplet busy-ns of one generated token.
    token_busy: Vec<f64>,
}

impl DecodeModel {
    /// Build the decode cost model for `cfg` against a shared sweep
    /// context, returning it with the full-prompt prefill stage graph
    /// (the deployment's reference pipeline, reused by the report).
    ///
    /// Decode serving needs a `seq<N>` dataset, a zoo model with at
    /// least one causal-attention layer (`file:` models pin their
    /// sequence length in the TOML, so they cannot express the `seq1`
    /// step graph), and no mixed `[serve] workloads`.
    pub fn build(cfg: &SiamConfig, ctx: &SweepContext) -> Result<(DecodeModel, StageGraph)> {
        cfg.validate()?;
        let dc = &cfg.decode;
        ensure!(
            cfg.serve.workloads.is_empty(),
            "decode serving does not mix with [serve] workloads (one decoder occupies \
             the whole system)"
        );
        ensure!(
            !cfg.dnn.model.starts_with("file:"),
            "decode serving needs a zoo decoder (file: models pin their sequence length, \
             so the seq1 decode-step graph cannot be derived)"
        );
        let ds = cfg.dnn.dataset.to_ascii_lowercase();
        let prompt_tokens: usize = ds
            .strip_prefix("seq")
            .and_then(|n| n.parse().ok())
            .filter(|&n| n > 0)
            .ok_or_else(|| {
                anyhow!(
                    "decode serving needs a token dataset 'seq<N>' (got '{}')",
                    cfg.dnn.dataset
                )
            })?;

        let full = StageGraph::build(cfg, ctx)?;
        let dnn = stage_dnn(cfg, ctx)?;
        let mut n_causal = 0usize;
        let mut dim = 0usize;
        let mut attn_names: BTreeSet<String> = BTreeSet::new();
        for l in &dnn.layers {
            if let LayerKind::CausalAttention { dim: d, .. } = l.kind {
                n_causal += 1;
                dim = d;
                attn_names.insert(l.name.clone());
            }
        }
        ensure!(
            n_causal > 0,
            "model '{}' has no causal-attention layers; decode serving needs a decoder \
             (gpt2_small)",
            cfg.dnn.model
        );

        // the decode-step pipeline: the same design point on a
        // single-token input, through the same shared caches
        let mut step_cfg = cfg.clone();
        step_cfg.dnn.dataset = "seq1".into();
        let step = StageGraph::build(&step_cfg, ctx)?;
        let var_ns = step.single_shot.circuit.latency_ns;
        let fixed_ns = (step.single_pass_ns() - var_ns).max(0.0);

        // chunked prefill: `prefill_chunk` tokens per pass trade buffer
        // pressure for extra passes (0 = whole prompt in one pass);
        // chunk graphs approximate each pass's attention at chunk length
        let (prefill_ns, prefill_energy_pj, prefill_chunks) =
            if dc.prefill_chunk == 0 || dc.prefill_chunk >= prompt_tokens {
                (full.single_pass_ns(), full.dynamic_energy_pj, 1)
            } else {
                let whole = prompt_tokens / dc.prefill_chunk;
                let rem = prompt_tokens % dc.prefill_chunk;
                let mut c_cfg = cfg.clone();
                c_cfg.dnn.dataset = format!("seq{}", dc.prefill_chunk);
                let cg = StageGraph::build(&c_cfg, ctx)?;
                let mut ns = whole as f64 * cg.single_pass_ns();
                let mut e = whole as f64 * cg.dynamic_energy_pj;
                let mut chunks = whole;
                if rem > 0 {
                    let mut r_cfg = cfg.clone();
                    r_cfg.dnn.dataset = format!("seq{rem}");
                    let rg = StageGraph::build(&r_cfg, ctx)?;
                    ns += rg.single_pass_ns();
                    e += rg.dynamic_energy_pj;
                    chunks += 1;
                }
                (ns, e, chunks)
            };

        // share-weighted per-chiplet busy-ns of one pass of a graph
        let busy_of = |g: &StageGraph| -> Vec<f64> {
            let mut v = vec![0.0f64; g.num_chiplets];
            for s in &g.stages {
                for &(c, x) in &s.shares {
                    let cap = g.chiplet_capacities_xbars[c].max(1) as f64;
                    v[c] += s.service_ns * x as f64 / cap;
                }
            }
            v
        };
        let mut prefill_busy = busy_of(&full);
        let scale = prefill_ns / full.single_pass_ns().max(1e-9);
        for b in &mut prefill_busy {
            *b *= scale;
        }
        let token_busy = busy_of(&step);

        // the chiplets whose causal-attention shares read the KV cache
        // every step — their on-chip reads cross the interposer from
        // the global-buffer port (chiplet 0)
        let mut kvset: BTreeSet<usize> = BTreeSet::new();
        for s in &step.stages {
            if s.layer.is_some() && attn_names.contains(&s.name) {
                for &(c, _) in &s.shares {
                    kvset.insert(c);
                }
            }
        }

        let nop = &cfg.system.nop;
        let model = DecodeModel {
            prompt_tokens,
            max_new_tokens: dc.max_new_tokens,
            kv_bytes_per_token: (2 * n_causal * dim * dc.kv_precision_bits).div_ceil(8),
            kv_capacity_bytes: cfg.system.global_buffer_kb * 1024,
            prefill_ns,
            prefill_energy_pj,
            prefill_chunks,
            fixed_ns,
            var_ns,
            token_energy_pj: step.dynamic_energy_pj,
            kv_chiplets: kvset.into_iter().collect(),
            num_chiplets: step.num_chiplets,
            mesh: Mesh::new(step.num_chiplets.max(1)),
            nop_clock_ns: 1.0e3 / nop.frequency_mhz,
            nop_ebit_pj: nop.ebit_pj,
            nop_bits_per_cycle: nop.bits_per_cycle().max(1),
            dram: cfg.dram.clone(),
            prefill_busy,
            token_busy,
        };
        Ok((model, full))
    }

    /// KV-cache bytes a batch with the given per-request context
    /// lengths (prompt + generated tokens) holds.
    pub fn kv_residency_bytes(&self, contexts: &[usize]) -> usize {
        contexts.iter().map(|&c| self.kv_bytes_per_token * c).sum()
    }

    /// The deterministic cost of one decode step over `contexts` (one
    /// context length per batched request): fixed overhead + occupancy
    /// · per-request compute + DRAM spill re-read + on-chip KV NoP
    /// epoch (simulated through the shared epoch `cache`).
    pub fn step_cost(&self, contexts: &[usize], cache: &EpochCache) -> StepCost {
        let residency = self.kv_residency_bytes(contexts);
        let overflow = residency.saturating_sub(self.kv_capacity_bytes);
        let (spill_latency_ns, spill_energy_pj) = if overflow > 0 {
            let d = crate::dram::estimate_with(overflow, &self.dram);
            (d.latency_ns, d.energy_pj)
        } else {
            (0.0, 0.0)
        };

        // the on-chip share streams from the global-buffer port
        // (chiplet 0) to every causal-attention chiplet; a co-located
        // share reads locally and pays no interposer hop
        let resident_bits = (residency - overflow) as u64 * 8;
        let remote: Vec<u32> =
            self.kv_chiplets.iter().filter(|&&c| c != 0).map(|&c| c as u32).collect();
        let (kv_nop_ns, kv_nop_energy_pj) = if resident_bits > 0 && !remote.is_empty() {
            let per_chiplet_bits = resident_bits.div_ceil(self.kv_chiplets.len() as u64);
            let count = per_chiplet_bits.div_ceil(self.nop_bits_per_cycle).max(1);
            let mut flows: Vec<Flow> = remote
                .iter()
                .map(|&c| Flow { src: 0, dst: c, count, start: 0, stride: 2 })
                .collect();
            canonicalize_flows(&mut flows);
            let r = PacketSim::new(&self.mesh).run_cached(&flows, cache);
            (
                r.completion_cycles as f64 * self.nop_clock_ns,
                (per_chiplet_bits * remote.len() as u64) as f64 * self.nop_ebit_pj,
            )
        } else {
            (0.0, 0.0)
        };

        let b = contexts.len() as f64;
        StepCost {
            latency_ns: self.fixed_ns + b * self.var_ns + spill_latency_ns + kv_nop_ns,
            energy_pj: b * self.token_energy_pj + spill_energy_pj + kv_nop_energy_pj,
            residency_bytes: residency,
            spill_bytes: overflow,
            spill_latency_ns,
            spill_energy_pj,
            kv_nop_ns,
            kv_nop_energy_pj,
        }
    }

    /// The analytic per-token latency of one isolated request: prefill
    /// plus every decode step at its exact context length, divided by
    /// the tokens generated. Closed-loop concurrency-1 serving delivers
    /// exactly `1e9 / per_token_ns` tokens/second (same cost helper in
    /// the same order — the acceptance identity).
    pub fn per_token_closed_form_ns(&self, cache: &EpochCache) -> f64 {
        let n = self.max_new_tokens;
        let mut total = self.prefill_ns;
        for t in 1..n {
            total += self.step_cost(&[self.prompt_tokens + t], cache).latency_ns;
        }
        total / n as f64
    }
}

/// Token-level generation metrics of one decode-serving run, attached
/// to the [`ServeReport`] as its `decode` block (`None` on classic
/// per-request serving, keeping that JSON byte-identical).
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeReport {
    /// Tokens generated per request (`[decode] max_new_tokens`).
    pub max_new_tokens: usize,
    /// KV-cache precision, bits per element.
    pub kv_precision_bits: usize,
    /// Continuous-batching occupancy cap.
    pub batch_cap: usize,
    /// Prefill chunk length (0 = whole prompt in one pass).
    pub prefill_chunk: usize,
    /// Prompt length from the `seq<N>` dataset, tokens.
    pub prompt_tokens: usize,
    /// Graph passes one prefill takes under chunking.
    pub prefill_chunks: usize,
    /// Latency of one whole-prompt prefill, ns.
    pub prefill_ns: f64,
    /// Per-step overhead paid once regardless of occupancy, ns.
    pub decode_fixed_ns: f64,
    /// Per-request compute latency of one decode step, ns.
    pub decode_var_ns: f64,
    /// Analytic per-token latency of one isolated request, ns.
    pub per_token_ns: f64,
    /// Tokens generated across the run.
    pub total_tokens: u64,
    /// Decode steps executed.
    pub decode_steps: u64,
    /// Delivered tokens per second over the serving window.
    pub tokens_per_second: f64,
    /// Time-to-first-token p50, ms.
    pub ttft_p50_ms: f64,
    /// Time-to-first-token p95, ms.
    pub ttft_p95_ms: f64,
    /// Time-to-first-token p99, ms.
    pub ttft_p99_ms: f64,
    /// Time-per-output-token p50, ms.
    pub tpot_p50_ms: f64,
    /// Time-per-output-token p95, ms.
    pub tpot_p95_ms: f64,
    /// Time-per-output-token p99, ms.
    pub tpot_p99_ms: f64,
    /// Mean batch occupancy across decode steps.
    pub occupancy_mean: f64,
    /// Peak batch occupancy.
    pub occupancy_peak: usize,
    /// KV bytes one cached token costs.
    pub kv_bytes_per_token: usize,
    /// Global-buffer capacity the cache is charged against, bytes.
    pub kv_capacity_bytes: usize,
    /// Peak KV residency across decode steps, bytes.
    pub kv_peak_bytes: usize,
    /// Peak single-step DRAM spill, bytes (0 = always fit on chip).
    pub kv_spill_bytes_peak: usize,
    /// Total DRAM spill re-read latency, ns.
    pub spill_latency_ns: f64,
    /// Total DRAM spill re-read energy, pJ.
    pub spill_energy_pj: f64,
    /// Total interposer latency of on-chip KV reads, ns.
    pub kv_nop_ns: f64,
    /// Total interposer energy of on-chip KV reads, pJ.
    pub kv_nop_energy_pj: f64,
}

impl DecodeReport {
    /// The report as a JSON object (all fields, snake_case).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("max_new_tokens", self.max_new_tokens as u64);
        o.set("kv_precision_bits", self.kv_precision_bits as u64);
        o.set("batch_cap", self.batch_cap as u64);
        o.set("prefill_chunk", self.prefill_chunk as u64);
        o.set("prompt_tokens", self.prompt_tokens as u64);
        o.set("prefill_chunks", self.prefill_chunks as u64);
        o.set("prefill_ns", self.prefill_ns);
        o.set("decode_fixed_ns", self.decode_fixed_ns);
        o.set("decode_var_ns", self.decode_var_ns);
        o.set("per_token_ns", self.per_token_ns);
        o.set("total_tokens", self.total_tokens);
        o.set("decode_steps", self.decode_steps);
        o.set("tokens_per_second", self.tokens_per_second);
        o.set("ttft_p50_ms", self.ttft_p50_ms);
        o.set("ttft_p95_ms", self.ttft_p95_ms);
        o.set("ttft_p99_ms", self.ttft_p99_ms);
        o.set("tpot_p50_ms", self.tpot_p50_ms);
        o.set("tpot_p95_ms", self.tpot_p95_ms);
        o.set("tpot_p99_ms", self.tpot_p99_ms);
        o.set("occupancy_mean", self.occupancy_mean);
        o.set("occupancy_peak", self.occupancy_peak as u64);
        o.set("kv_bytes_per_token", self.kv_bytes_per_token as u64);
        o.set("kv_capacity_bytes", self.kv_capacity_bytes as u64);
        o.set("kv_peak_bytes", self.kv_peak_bytes as u64);
        o.set("kv_spill_bytes_peak", self.kv_spill_bytes_peak as u64);
        o.set("spill_latency_ns", self.spill_latency_ns);
        o.set("spill_energy_pj", self.spill_energy_pj);
        o.set("kv_nop_ns", self.kv_nop_ns);
        o.set("kv_nop_energy_pj", self.kv_nop_energy_pj);
        o
    }
}

/// One batched request mid-generation.
struct Slot {
    req: u32,
    arrival_ns: f64,
    prefill_end_ns: f64,
    tokens: usize,
}

/// Raw statistics of one decode-engine run.
#[derive(Default)]
struct DecodeRun {
    offered: usize,
    completed: usize,
    shed: usize,
    failover_shed: usize,
    total_tokens: u64,
    decode_steps: u64,
    prefills: usize,
    end_ns: f64,
    latencies_ns: Vec<f64>,
    completion_times_ns: Vec<f64>,
    ttft_ns: Vec<f64>,
    tpot_ns: Vec<f64>,
    occupancy_sum: u64,
    occupancy_peak: usize,
    kv_peak_bytes: usize,
    kv_spill_bytes_peak: usize,
    spill_latency_ns: f64,
    spill_energy_pj: f64,
    kv_nop_ns: f64,
    kv_nop_energy_pj: f64,
    resume_time_ns: Option<f64>,
}

/// Everything the engine needs up front: the healthy model, the
/// prebuilt remap target (failure scenario only), and the open-loop
/// arrival stream (`None` = closed loop).
struct DecodePlan<'a> {
    model: &'a DecodeModel,
    degraded: Option<&'a DecodeModel>,
    arrivals: Option<&'a [f64]>,
    fail_time_ns: Option<f64>,
    remap_ns: f64,
}

/// The iteration-level continuous-batching event loop.
struct Engine<'a, S: DecodeSink> {
    sc: &'a ServeConfig,
    model: &'a DecodeModel,
    cache: &'a EpochCache,
    sink: &'a mut S,
    /// Batch occupancy cap (`[decode] batch_cap`).
    cap: usize,
    /// Closed-loop mode: clients re-issue on completion, nothing sheds.
    closed: bool,
    batch: Vec<Slot>,
    waiting: VecDeque<(u32, f64)>,
    /// Closed-loop requests issued so far.
    spawned: usize,
    t: f64,
    run: DecodeRun,
}

impl<S: DecodeSink> Engine<'_, S> {
    /// Admit every open-loop arrival at or before the current time,
    /// shedding beyond the `[serve] queue_depth` waiting bound.
    fn admit_open(&mut self, arrivals: &[f64], next: &mut usize) {
        while *next < arrivals.len() && arrivals[*next] <= self.t {
            let req = *next as u32;
            let at = arrivals[*next];
            if self.waiting.len() >= self.sc.queue_depth {
                self.run.shed += 1;
                self.sink.shed(at, req);
            } else {
                self.waiting.push_back((req, at));
                self.sink.admitted(at, req);
            }
            *next += 1;
        }
    }

    /// Fill free batch slots from the waiting queue, one sequential
    /// prefill pass each (the first generated token falls out of
    /// prefill, so TTFT is measured here).
    fn fill_batch(&mut self) {
        while self.batch.len() < self.cap && !self.waiting.is_empty() {
            let (req, arrival_ns) = self.waiting.pop_front().expect("checked non-empty");
            let start = self.t;
            self.t += self.model.prefill_ns;
            self.run.prefills += 1;
            self.sink.prefill(start, self.model.prefill_ns, req);
            self.run.total_tokens += 1;
            self.sink.token(self.t, req, 1);
            self.run.ttft_ns.push(self.t - arrival_ns);
            self.batch.push(Slot { req, arrival_ns, prefill_end_ns: self.t, tokens: 1 });
        }
    }

    /// Run one decode step over the live batch, advancing every
    /// request by one token.
    fn step(&mut self) {
        let contexts: Vec<usize> =
            self.batch.iter().map(|s| self.model.prompt_tokens + s.tokens).collect();
        let cost = self.model.step_cost(&contexts, self.cache);
        let start = self.t;
        self.t += cost.latency_ns;
        self.run.decode_steps += 1;
        self.run.occupancy_sum += self.batch.len() as u64;
        self.run.occupancy_peak = self.run.occupancy_peak.max(self.batch.len());
        self.run.kv_peak_bytes = self.run.kv_peak_bytes.max(cost.residency_bytes);
        self.run.kv_spill_bytes_peak = self.run.kv_spill_bytes_peak.max(cost.spill_bytes);
        self.run.spill_latency_ns += cost.spill_latency_ns;
        self.run.spill_energy_pj += cost.spill_energy_pj;
        self.run.kv_nop_ns += cost.kv_nop_ns;
        self.run.kv_nop_energy_pj += cost.kv_nop_energy_pj;
        self.sink.step(start, cost.latency_ns, self.batch.len());
        for slot in &mut self.batch {
            slot.tokens += 1;
            self.run.total_tokens += 1;
            self.sink.token(self.t, slot.req, slot.tokens);
        }
    }

    /// Retire every request that has all its tokens; closed-loop
    /// clients immediately re-issue at the completion time.
    fn retire(&mut self) {
        let n = self.model.max_new_tokens;
        let mut i = 0;
        while i < self.batch.len() {
            if self.batch[i].tokens < n {
                i += 1;
                continue;
            }
            let s = self.batch.remove(i);
            let latency = self.t - s.arrival_ns;
            self.run.completed += 1;
            self.run.latencies_ns.push(latency);
            self.run.completion_times_ns.push(self.t);
            if s.tokens > 1 {
                self.run.tpot_ns.push((self.t - s.prefill_end_ns) / (s.tokens - 1) as f64);
            }
            self.sink.completed(self.t, s.req, latency);
            if self.closed && self.spawned < self.sc.requests {
                let req = self.spawned as u32;
                self.spawned += 1;
                self.run.offered += 1;
                self.waiting.push_back((req, self.t));
                self.sink.admitted(self.t, req);
            }
        }
    }

    /// Shed the in-flight batch and waiting queue at the failure
    /// instant (in-flight counts separately for the failover report).
    fn shed_all(&mut self) -> usize {
        let mut n = 0;
        for s in self.batch.drain(..) {
            self.run.failover_shed += 1;
            n += 1;
            self.sink.shed(self.t, s.req);
        }
        for (req, _) in self.waiting.drain(..) {
            self.run.shed += 1;
            n += 1;
            self.sink.shed(self.t, req);
        }
        n
    }
}

/// Run the continuous-batching decode engine to drain, returning the
/// raw run statistics.
fn run_decode<S: DecodeSink>(
    sc: &ServeConfig,
    dec: &DecodeConfig,
    plan: &DecodePlan<'_>,
    cache: &EpochCache,
    sink: &mut S,
) -> DecodeRun {
    let closed = plan.arrivals.is_none();
    let mut eng = Engine {
        sc,
        model: plan.model,
        cache,
        sink,
        cap: dec.batch_cap.max(1),
        closed,
        batch: Vec::new(),
        waiting: VecDeque::new(),
        spawned: 0,
        t: 0.0,
        run: DecodeRun::default(),
    };

    let mut next_arrival = 0usize;
    if closed {
        let initial = sc.concurrency.min(sc.requests).max(1);
        for _ in 0..initial {
            let req = eng.spawned as u32;
            eng.spawned += 1;
            eng.run.offered += 1;
            eng.waiting.push_back((req, 0.0));
            eng.sink.admitted(0.0, req);
        }
    } else {
        eng.run.offered = plan.arrivals.map_or(0, <[f64]>::len);
    }

    let mut failed = false;
    loop {
        // mid-run chiplet failure: shed everything in flight, then
        // either hot-swap the prebuilt remapped model after the remap
        // latency or stay down for the rest of the stream
        if let Some(ft) = plan.fail_time_ns {
            if !failed && eng.t >= ft {
                failed = true;
                let lost = eng.shed_all();
                eng.sink.failed(eng.t, lost);
                match plan.degraded {
                    Some(m) => {
                        eng.model = m;
                        eng.t = ft + plan.remap_ns;
                        eng.run.resume_time_ns = Some(eng.t);
                        eng.sink.resumed(eng.t);
                    }
                    None => {
                        if let Some(arr) = plan.arrivals {
                            while next_arrival < arr.len() {
                                eng.run.shed += 1;
                                eng.sink.shed(arr[next_arrival], next_arrival as u32);
                                next_arrival += 1;
                            }
                        }
                        break;
                    }
                }
            }
        }

        if let Some(arr) = plan.arrivals {
            eng.admit_open(arr, &mut next_arrival);
        }
        eng.fill_batch();
        eng.retire();
        if !eng.batch.is_empty() {
            eng.step();
            eng.retire();
            continue;
        }
        if !eng.waiting.is_empty() {
            continue;
        }
        match plan.arrivals {
            Some(arr) if next_arrival < arr.len() => {
                eng.t = eng.t.max(arr[next_arrival]);
            }
            _ => break,
        }
    }
    eng.run.end_ns = eng.t;
    eng.run
}

/// Precomputed per-run context the report assembly needs alongside the
/// raw statistics.
struct RunEnv {
    mode: &'static str,
    offered_qps: f64,
    concurrency: usize,
    per_token_ns: f64,
    failover: Option<FailoverReport>,
}

/// Turn raw decode-engine statistics into a full [`ServeReport`] with
/// its `decode` block attached.
fn assemble_decode_report(
    cfg: &SiamConfig,
    model: &DecodeModel,
    full: &StageGraph,
    run: &DecodeRun,
    env: RunEnv,
    t0: std::time::Instant,
) -> ServeReport {
    let sort = |v: &[f64]| {
        let mut s = v.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        s
    };
    let lat = sort(&run.latencies_ns);
    let ttft = sort(&run.ttft_ns);
    let tpot = sort(&run.tpot_ns);
    let mean_ns = if lat.is_empty() {
        0.0
    } else {
        lat.iter().sum::<f64>() / lat.len() as f64
    };

    let window_ns = run.end_ns.max(1e-9);
    let mut util = vec![0.0f64; full.num_chiplets];
    for (c, u) in util.iter_mut().enumerate() {
        let busy = run.prefills as f64 * model.prefill_busy[c]
            + run.total_tokens as f64 * model.token_busy[c];
        *u = (busy / window_ns).min(1.0);
    }
    let mean_utilization = if util.is_empty() {
        0.0
    } else {
        util.iter().sum::<f64>() / util.len() as f64
    };
    let peak_utilization = util.iter().copied().fold(0.0f64, f64::max);

    let total_energy_pj = run.prefills as f64 * model.prefill_energy_pj
        + run.total_tokens as f64 * model.token_energy_pj
        + run.spill_energy_pj
        + run.kv_nop_energy_pj;
    let leak_share_pj = if run.completed > 0 {
        full.leakage_uw * window_ns / run.completed as f64 / 1.0e3
    } else {
        0.0
    };
    let energy_per_inference_pj = if run.completed > 0 {
        total_energy_pj / run.completed as f64 + leak_share_pj
    } else {
        0.0
    };

    let decode = DecodeReport {
        max_new_tokens: cfg.decode.max_new_tokens,
        kv_precision_bits: cfg.decode.kv_precision_bits,
        batch_cap: cfg.decode.batch_cap,
        prefill_chunk: cfg.decode.prefill_chunk,
        prompt_tokens: model.prompt_tokens,
        prefill_chunks: model.prefill_chunks,
        prefill_ns: model.prefill_ns,
        decode_fixed_ns: model.fixed_ns,
        decode_var_ns: model.var_ns,
        per_token_ns: env.per_token_ns,
        total_tokens: run.total_tokens,
        decode_steps: run.decode_steps,
        tokens_per_second: run.total_tokens as f64 * 1.0e9 / window_ns,
        ttft_p50_ms: percentile(&ttft, 50.0) / 1.0e6,
        ttft_p95_ms: percentile(&ttft, 95.0) / 1.0e6,
        ttft_p99_ms: percentile(&ttft, 99.0) / 1.0e6,
        tpot_p50_ms: percentile(&tpot, 50.0) / 1.0e6,
        tpot_p95_ms: percentile(&tpot, 95.0) / 1.0e6,
        tpot_p99_ms: percentile(&tpot, 99.0) / 1.0e6,
        occupancy_mean: if run.decode_steps > 0 {
            run.occupancy_sum as f64 / run.decode_steps as f64
        } else {
            0.0
        },
        occupancy_peak: run.occupancy_peak,
        kv_bytes_per_token: model.kv_bytes_per_token,
        kv_capacity_bytes: model.kv_capacity_bytes,
        kv_peak_bytes: run.kv_peak_bytes,
        kv_spill_bytes_peak: run.kv_spill_bytes_peak,
        spill_latency_ns: run.spill_latency_ns,
        spill_energy_pj: run.spill_energy_pj,
        kv_nop_ns: run.kv_nop_ns,
        kv_nop_energy_pj: run.kv_nop_energy_pj,
    };

    let (bottleneck_stage, bottleneck_service_ns) = full.bottleneck();
    ServeReport {
        model: full.single_shot.model.clone(),
        dataset: full.single_shot.dataset.clone(),
        model_source: full.single_shot.model_source.clone(),
        mode: env.mode.into(),
        offered_qps: env.offered_qps,
        concurrency: env.concurrency,
        num_stages: full.stages.len(),
        num_chiplets: full.num_chiplets,
        classes: full.single_shot.chiplets_per_class.clone(),
        bottleneck_stage,
        bottleneck_service_ns,
        bottleneck_qps: full.bottleneck_qps(),
        single_pass_ns: full.single_pass_ns(),
        single_shot_latency_ns: full.single_shot.total.latency_ns,
        single_shot_energy_pj: full.single_shot.total.energy_pj,
        requests: run.offered,
        completed: run.completed,
        dropped: run.shed + run.failover_shed,
        throughput_qps: run.completed as f64 * 1.0e9 / window_ns,
        p50_ms: percentile(&lat, 50.0) / 1.0e6,
        p95_ms: percentile(&lat, 95.0) / 1.0e6,
        p99_ms: percentile(&lat, 99.0) / 1.0e6,
        mean_ms: mean_ns / 1.0e6,
        chiplet_utilization: util,
        mean_utilization,
        peak_utilization,
        energy_per_inference_pj,
        qos_p99_target_ms: cfg.serve.qos_p99_ms,
        weight_load: full.weight_load,
        failover: env.failover,
        decode: Some(decode),
        variation: full.variation.clone(),
        wall_seconds: t0.elapsed().as_secs_f64(),
        meta: None,
    }
}

/// Run decode serving for one configuration against a shared sweep
/// context (the decode analogue of [`crate::serve::evaluate`]).
pub fn evaluate_decode(cfg: &SiamConfig, ctx: &SweepContext) -> Result<ServeReport> {
    let t0 = std::time::Instant::now();
    let (model, full) = DecodeModel::build(cfg, ctx)?;
    decode_graph(cfg, ctx, &model, &full, &mut NoopDecodeSink, t0)
}

/// [`evaluate_decode`] with the token-level event stream rendered into
/// a Chrome trace (see [`DecodeTracer`]). The report is bit-identical
/// to [`evaluate_decode`]'s.
pub fn evaluate_decode_traced(
    cfg: &SiamConfig,
    ctx: &SweepContext,
) -> Result<(ServeReport, TraceBuffer)> {
    let t0 = std::time::Instant::now();
    let (model, full) = DecodeModel::build(cfg, ctx)?;
    let mut tracer = DecodeTracer::new();
    let report = decode_graph(cfg, ctx, &model, &full, &mut tracer, t0)?;
    Ok((report, tracer.into_buffer()))
}

/// Run decode serving for one configuration, building a fresh
/// [`SweepContext`] (the decode analogue of [`crate::serve::serve`]).
/// A `[sweep] cache_file` on the config is honored.
pub fn serve_decode(cfg: &SiamConfig) -> Result<ServeReport> {
    let ctx = SweepContext::new(cfg)?;
    let store = crate::serve::open_store(cfg, &ctx)?;
    let report = evaluate_decode(cfg, &ctx)?;
    if let Some(s) = &store {
        s.absorb(ctx.epoch_cache())?;
    }
    Ok(report)
}

/// [`serve_decode`] with the token-level event stream rendered into a
/// Chrome trace — the entry point behind `siam serve --decode --trace`.
pub fn serve_decode_traced(cfg: &SiamConfig) -> Result<(ServeReport, TraceBuffer)> {
    let ctx = SweepContext::new(cfg)?;
    let store = crate::serve::open_store(cfg, &ctx)?;
    let out = evaluate_decode_traced(cfg, &ctx)?;
    if let Some(s) = &store {
        s.absorb(ctx.epoch_cache())?;
    }
    Ok(out)
}

/// Shared tail of the decode entry points: plan the workload (and the
/// failure scenario, if configured), run the engine, assemble the
/// report, and attach the run's `meta` block.
fn decode_graph<S: DecodeSink>(
    cfg: &SiamConfig,
    ctx: &SweepContext,
    model: &DecodeModel,
    full: &StageGraph,
    sink: &mut S,
    t0: std::time::Instant,
) -> Result<ServeReport> {
    let sc = &cfg.serve;
    let cache = ctx.epoch_cache();
    let per_token_ns = model.per_token_closed_form_ns(cache);
    let request_ns = per_token_ns * cfg.decode.max_new_tokens as f64;

    let (arrivals, mode, offered_qps, concurrency) = match sc.mode {
        ServeMode::Open => {
            // auto rate: 80 % of the sequential single-request service
            // rate — loaded but stable, batching headroom on top
            let rate = if sc.rate_qps > 0.0 {
                sc.rate_qps
            } else {
                0.8e9 / request_ns
            };
            (Some(poisson_arrivals(rate, sc.requests, sc.seed)), "open", rate, 0)
        }
        ServeMode::Closed => (None, "closed", 0.0, sc.concurrency),
    };

    // the failure scenario: prebuild the remapped model exactly like
    // the classic path prebuilds its degraded stage graph
    let mut fail_time_ns = None;
    let mut degraded = None;
    let mut remap_error = None;
    if let Some(fail_at) = sc.fail_at_request {
        let arr = arrivals
            .as_deref()
            .ok_or_else(|| anyhow!("decode failover needs open-loop serving ([serve] mode)"))?;
        ensure!(
            fail_at < sc.requests,
            "serve.fail_at_request = {fail_at} is outside the {} offered requests",
            sc.requests
        );
        ensure!(
            sc.fail_chiplet < model.num_chiplets,
            "serve.fail_chiplet = {} but the architecture has {} chiplets (spares included)",
            sc.fail_chiplet,
            model.num_chiplets
        );
        fail_time_ns = Some(arr[fail_at]);
        let mut dcfg = cfg.clone();
        dcfg.serve.fail_at_request = None;
        if !dcfg.fault.kill_chiplets.contains(&sc.fail_chiplet) {
            dcfg.fault.kill_chiplets.push(sc.fail_chiplet);
        }
        match DecodeModel::build(&dcfg, ctx) {
            Ok((m, _)) => degraded = Some(m),
            Err(e) => remap_error = Some(format!("{e:#}")),
        }
    }

    let plan = DecodePlan {
        model,
        degraded: degraded.as_ref(),
        arrivals: arrivals.as_deref(),
        fail_time_ns,
        remap_ns: sc.remap_latency_us * 1.0e3,
    };
    let run = run_decode(sc, &cfg.decode, &plan, cache, sink);

    let failover = fail_time_ns.map(|ft| {
        let dead_stages = full
            .stages
            .iter()
            .filter(|s| s.shares.iter().any(|&(c, _)| c == sc.fail_chiplet))
            .count();
        let resume = run.resume_time_ns;
        let (mut before, mut during, mut after) = (Vec::new(), Vec::new(), Vec::new());
        let mut first_after_ns = f64::INFINITY;
        for (&t, &l) in run.completion_times_ns.iter().zip(&run.latencies_ns) {
            if t < ft {
                before.push(l);
            } else if resume.is_none_or(|rt| t < rt) {
                during.push(l);
            } else {
                first_after_ns = first_after_ns.min(t);
                after.push(l);
            }
        }
        for w in [&mut before, &mut during, &mut after] {
            w.sort_by(|a, b| a.total_cmp(b));
        }
        let recovered = !after.is_empty();
        FailoverReport {
            fail_chiplet: sc.fail_chiplet,
            fail_time_ms: ft / 1.0e6,
            remap_latency_ms: sc.remap_latency_us / 1.0e3,
            dead_stages,
            recovered,
            recovery_ms: if recovered { (first_after_ns - ft) / 1.0e6 } else { 0.0 },
            shed_total: run.failover_shed + run.shed,
            shed_in_flight: run.failover_shed,
            p99_before_ms: percentile(&before, 99.0) / 1.0e6,
            p99_during_ms: percentile(&during, 99.0) / 1.0e6,
            p99_after_ms: percentile(&after, 99.0) / 1.0e6,
            spare_chiplets: cfg.system.spare_chiplets,
            remap_error,
        }
    });

    let env = RunEnv { mode, offered_qps, concurrency, per_token_ns, failover };
    let mut report = assemble_decode_report(cfg, model, full, &run, env, t0);
    let mut meta = RunMeta::for_config(cfg);
    meta.model_source = full.single_shot.model_source.clone();
    meta.epoch_cache = Some(CacheSnapshot::capture(ctx.epoch_cache()));
    meta.engine_tiers = Some(full.single_shot.engine_tiers);
    meta.wall_seconds = t0.elapsed().as_secs_f64();
    report.meta = Some(meta);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A hand-built model with simple round numbers, for exact
    /// KV-accounting and step-cost arithmetic.
    fn synthetic(kv_bpt: usize, cap: usize, kv_chiplets: Vec<usize>) -> DecodeModel {
        DecodeModel {
            prompt_tokens: 4,
            max_new_tokens: 4,
            kv_bytes_per_token: kv_bpt,
            kv_capacity_bytes: cap,
            prefill_ns: 100.0,
            prefill_energy_pj: 10.0,
            prefill_chunks: 1,
            fixed_ns: 5.0,
            var_ns: 2.0,
            token_energy_pj: 1.0,
            kv_chiplets,
            num_chiplets: 4,
            mesh: Mesh::new(4),
            nop_clock_ns: 4.0,
            nop_ebit_pj: 0.54,
            nop_bits_per_cycle: 128,
            dram: SiamConfig::paper_default().dram,
            prefill_busy: vec![0.0; 4],
            token_busy: vec![0.0; 4],
        }
    }

    #[test]
    fn kv_residency_matches_closed_form() {
        let m = synthetic(64, 1 << 20, vec![]);
        assert_eq!(m.kv_residency_bytes(&[]), 0);
        assert_eq!(m.kv_residency_bytes(&[5]), 320);
        assert_eq!(m.kv_residency_bytes(&[5, 7, 9]), 64 * 21);
        // decode-step trajectory of one request: prompt 4, tokens 1..
        for t in 1..10usize {
            assert_eq!(m.kv_residency_bytes(&[4 + t]), 64 * (4 + t));
        }
    }

    #[test]
    fn kv_spill_boundary_is_one_byte_exact() {
        let cache = EpochCache::new();
        // 16 cached tokens at 64 B/token = exactly 1024 B
        let fit = synthetic(64, 1024, vec![]);
        let c = fit.step_cost(&[16], &cache);
        assert_eq!(c.residency_bytes, 1024);
        assert_eq!(c.spill_bytes, 0);
        assert_eq!(c.spill_latency_ns, 0.0);
        assert_eq!(c.spill_energy_pj, 0.0);
        // one byte less capacity: exactly one byte spills, and the DRAM
        // model charges real latency and energy for the re-read
        let over = synthetic(64, 1023, vec![]);
        let c = over.step_cost(&[16], &cache);
        assert_eq!(c.spill_bytes, 1);
        assert!(c.spill_latency_ns > 0.0);
        assert!(c.spill_energy_pj > 0.0);
        assert!(c.latency_ns > fit.step_cost(&[16], &cache).latency_ns);
    }

    #[test]
    fn step_cost_composes_fixed_var_and_nop() {
        let cache = EpochCache::new();
        // no KV chiplets, no spill: pure fixed + B·var
        let m = synthetic(64, 1 << 20, vec![]);
        for b in 1..5usize {
            let contexts = vec![8; b];
            let c = m.step_cost(&contexts, &cache);
            assert_eq!(c.latency_ns, 5.0 + b as f64 * 2.0);
            assert_eq!(c.energy_pj, b as f64);
            assert_eq!(c.kv_nop_ns, 0.0);
        }
        // a remote KV chiplet adds a NoP epoch with real latency/energy
        let r = synthetic(64, 1 << 20, vec![1, 2]);
        let c = r.step_cost(&[8], &cache);
        assert!(c.kv_nop_ns > 0.0);
        assert!(c.kv_nop_energy_pj > 0.0);
        assert!(c.latency_ns > 5.0 + 2.0);
        // a KV share co-located with the buffer port pays no NoP
        let local = synthetic(64, 1 << 20, vec![0]);
        let c = local.step_cost(&[8], &cache);
        assert_eq!(c.kv_nop_ns, 0.0);
        assert_eq!(c.kv_nop_energy_pj, 0.0);
    }

    #[test]
    fn per_token_closed_form_sums_step_trajectory() {
        let cache = EpochCache::new();
        let m = synthetic(64, 1 << 20, vec![]);
        // prompt 4, n 4: prefill + steps at contexts 5, 6, 7
        let want = (100.0
            + m.step_cost(&[5], &cache).latency_ns
            + m.step_cost(&[6], &cache).latency_ns
            + m.step_cost(&[7], &cache).latency_ns)
            / 4.0;
        assert_eq!(m.per_token_closed_form_ns(&cache), want);
    }

    fn decode_cfg() -> SiamConfig {
        SiamConfig::paper_default()
            .with_model("gpt2_small", "seq16")
            .with_decode(4, 8, 4)
            .with_serve_requests(8)
    }

    #[test]
    fn closed_loop_concurrency_one_matches_closed_form() {
        let cfg = decode_cfg().with_serve_closed(1);
        let rep = serve_decode(&cfg).unwrap();
        let d = rep.decode.as_ref().expect("decode block attached");
        let want = 1.0e9 / d.per_token_ns;
        let rel = (d.tokens_per_second - want).abs() / want;
        assert!(rel < 1e-9, "tokens/s {} vs closed form {want} (rel {rel})", d.tokens_per_second);
        assert_eq!(rep.dropped, 0);
        assert_eq!(rep.completed, 8);
        assert_eq!(d.total_tokens, 8 * 4);
        // concurrency 1 never batches, and TTFT is exactly the prefill
        assert_eq!(d.occupancy_peak, 1);
        let rel = (d.ttft_p50_ms - d.prefill_ns / 1.0e6).abs() / (d.prefill_ns / 1.0e6);
        assert!(rel < 1e-12, "ttft {} vs prefill {}", d.ttft_p50_ms, d.prefill_ns / 1.0e6);
        assert!(d.decode_fixed_ns > 0.0 && d.decode_var_ns > 0.0);
    }

    #[test]
    fn continuous_batching_conserves_and_respects_cap() {
        // open-loop auto rate: whatever the queue sheds or completes,
        // every offered request is accounted for at drain
        let cfg = decode_cfg().with_serve_open(0.0);
        let rep = serve_decode(&cfg).unwrap();
        let d = rep.decode.as_ref().unwrap();
        assert_eq!(rep.requests, rep.completed + rep.dropped, "conservation at drain");
        assert!(d.occupancy_peak <= 4, "occupancy {} exceeds cap", d.occupancy_peak);
        assert!(d.occupancy_mean <= d.occupancy_peak as f64);
        assert!(d.tokens_per_second > 0.0);
        assert!(d.kv_peak_bytes >= d.kv_bytes_per_token * (16 + 1));
        // the decode block appears exactly once in the JSON
        let j = rep.to_json().to_string_pretty();
        assert_eq!(j.matches("\"decode\"").count(), 1);
        assert_eq!(j.matches("\"kv_spill_bytes_peak\"").count(), 1);
        let back = crate::util::json::parse(&j).expect("decode JSON parses");
        let db = back.get("decode").expect("decode key");
        assert!(db.get("tokens_per_second").and_then(|v| v.as_f64()).is_some());
    }

    #[test]
    fn batching_amortizes_fixed_cost() {
        let base = decode_cfg();
        let seq = serve_decode(&base.clone().with_serve_closed(1)).unwrap();
        let bat = serve_decode(&base.with_serve_closed(4)).unwrap();
        let (ds, db) = (seq.decode.as_ref().unwrap(), bat.decode.as_ref().unwrap());
        assert!(db.occupancy_peak > 1, "closed-4 must batch");
        assert!(
            db.tokens_per_second > ds.tokens_per_second,
            "batched {} vs sequential {} tokens/s",
            db.tokens_per_second,
            ds.tokens_per_second
        );
    }

    #[test]
    fn decode_seed_determinism_bitwise() {
        let cfg = decode_cfg().with_serve_open(0.0);
        let a = serve_decode(&cfg).unwrap();
        let b = serve_decode(&cfg).unwrap();
        let (da, db) = (a.decode.as_ref().unwrap(), b.decode.as_ref().unwrap());
        assert_eq!(da.tokens_per_second.to_bits(), db.tokens_per_second.to_bits());
        assert_eq!(da.ttft_p99_ms.to_bits(), db.ttft_p99_ms.to_bits());
        assert_eq!(da.tpot_p99_ms.to_bits(), db.tpot_p99_ms.to_bits());
        assert_eq!(da.total_tokens, db.total_tokens);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
    }

    #[test]
    fn decode_gating_rejects_non_decoders() {
        // image datasets / models without causal attention are rejected
        // with actionable messages before any engine work
        let cfg = SiamConfig::paper_default().with_decode(4, 8, 4);
        let ctx = SweepContext::new(&cfg).unwrap();
        let e = DecodeModel::build(&cfg, &ctx).unwrap_err().to_string();
        assert!(e.contains("seq<N>"), "{e}");
        let mut wl = decode_cfg();
        wl.serve.workloads = vec!["lenet5:cifar10".into()];
        let ctx2 = SweepContext::new(&decode_cfg()).unwrap();
        let e = DecodeModel::build(&wl, &ctx2).unwrap_err().to_string();
        assert!(e.contains("workloads"), "{e}");
    }

    #[test]
    fn decode_trace_carries_token_events() {
        let cfg = decode_cfg().with_serve_closed(2).with_serve_requests(4);
        let (rep, buf) = serve_decode_traced(&cfg).unwrap();
        let text = buf.render();
        for ev in ["\"prefill\"", "\"decode-step\"", "\"token\"", "\"complete\""] {
            assert!(text.contains(ev), "trace missing {ev}");
        }
        // tracing is a pure observer
        let plain = serve_decode(&cfg).unwrap();
        let (dt, dp) = (rep.decode.as_ref().unwrap(), plain.decode.as_ref().unwrap());
        assert_eq!(dt.tokens_per_second.to_bits(), dp.tokens_per_second.to_bits());
        assert_eq!(dt.total_tokens, dp.total_tokens);
    }
}
