//! Inference-serving simulator: a deterministic discrete-event engine
//! that streams a workload of inference requests through the chiplet
//! system (the ROADMAP's "serve heavy traffic" scenario, which
//! single-shot latency cannot represent).
//!
//! Weight-stationary IMC pins each layer to its chiplet partition, so
//! successive requests pipeline across layer stages. [`stage`] turns a
//! mapped design point into that pipeline (per-stage service times from
//! the circuit / NoC / NoP / DRAM engines, through the shared sweep
//! caches); [`engine`] runs requests through it with bounded per-stage
//! queues and blocking back-pressure; [`traffic`] generates open-loop
//! Poisson arrivals from a seeded splitmix64 stream (closed-loop
//! traffic is self-clocked). The result is a
//! [`ServeReport`](crate::coordinator::ServeReport): throughput,
//! p50/p95/p99 latency, per-chiplet utilization and energy-per-inference
//! under load.
//!
//! Calibration invariants (asserted by tests and the `serve_saturation`
//! bench):
//!
//! * closed-loop concurrency 1 throughput = 1 / single-inference
//!   latency (within the ingress-fetch share, « 1 %);
//! * open-loop throughput plateaus at the analytic bottleneck-stage
//!   service rate once offered load exceeds it;
//! * fixed seed ⇒ bit-identical percentiles, on any machine and under
//!   any sweep thread count.

pub mod engine;
pub mod stage;
pub mod traffic;

pub use engine::{run, EngineParams, RunStats, Workload};
pub use stage::{StageGraph, StageSpec};
pub use traffic::{poisson_arrivals, SplitMix64};

use crate::config::{ServeConfig, ServeMode, SiamConfig};
use crate::coordinator::{ServeReport, SweepContext};
use anyhow::Result;

/// Nearest-rank percentile of an **ascending-sorted** latency slice.
/// Returns 0 for an empty slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Run the serving simulator for one configuration, building a fresh
/// [`SweepContext`]. Sweeping many points this way wastes the shared
/// caches — use [`evaluate`] against a shared context instead.
pub fn serve(cfg: &SiamConfig) -> Result<ServeReport> {
    let ctx = SweepContext::new(cfg)?;
    evaluate(cfg, &ctx)
}

/// Run the serving simulator for one configuration against a shared
/// sweep context: the stage service times come out of the context's
/// layer-cost / epoch / DRAM caches, so a point the sweep already
/// simulated costs only the event loop.
pub fn evaluate(cfg: &SiamConfig, ctx: &SweepContext) -> Result<ServeReport> {
    let graph = StageGraph::build(cfg, ctx)?;
    Ok(run_graph(&graph, &cfg.serve))
}

/// Run the serving engine on a prebuilt [`StageGraph`] — the QoS sweep
/// builds each point's graph once (it carries the single-shot report
/// too) and calls this, so QoS ranking adds only the event loop.
pub fn run_graph(graph: &StageGraph, sc: &ServeConfig) -> ServeReport {
    let t0 = std::time::Instant::now();
    let services: Vec<f64> = graph.stages.iter().map(|s| s.service_ns).collect();
    let (workload, mode, offered_qps, concurrency) = match sc.mode {
        ServeMode::Open => {
            let rate = if sc.rate_qps > 0.0 {
                sc.rate_qps
            } else {
                // auto: 80 % of the analytic ceiling — loaded but stable
                0.8 * graph.bottleneck_qps()
            };
            (
                Workload::Open {
                    arrivals: poisson_arrivals(rate, sc.requests, sc.seed),
                },
                "open",
                rate,
                0,
            )
        }
        ServeMode::Closed => (
            Workload::Closed { concurrency: sc.concurrency, requests: sc.requests },
            "closed",
            0.0,
            sc.concurrency,
        ),
    };

    let stats = run(&services, EngineParams { queue_depth: sc.queue_depth }, workload);

    let mut sorted = stats.latencies_ns.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mean_ns = if sorted.is_empty() {
        0.0
    } else {
        sorted.iter().sum::<f64>() / sorted.len() as f64
    };

    // crossbar-weighted per-chiplet busy fraction over the window
    // (per-chiplet capacity denominators — classes differ in size)
    let window_ns = stats.window_ns().max(1e-9);
    let mut util = vec![0.0f64; graph.num_chiplets];
    for (spec, &busy) in graph.stages.iter().zip(&stats.stage_busy_ns) {
        for &(c, xbars) in &spec.shares {
            let cap = graph.chiplet_capacities_xbars[c].max(1) as f64;
            util[c] += busy * xbars as f64 / (cap * window_ns);
        }
    }
    let mean_utilization = if util.is_empty() {
        0.0
    } else {
        util.iter().sum::<f64>() / util.len() as f64
    };
    let peak_utilization = util.iter().copied().fold(0.0f64, f64::max);

    let completed = stats.completed;
    let leak_share_pj = if completed > 0 {
        graph.leakage_uw * stats.window_ns() / completed as f64 / 1.0e3
    } else {
        0.0
    };
    let (bottleneck_stage, bottleneck_service_ns) = graph.bottleneck();

    ServeReport {
        model: graph.single_shot.model.clone(),
        dataset: graph.single_shot.dataset.clone(),
        model_source: graph.single_shot.model_source.clone(),
        mode: mode.into(),
        offered_qps,
        concurrency,
        num_stages: graph.stages.len(),
        num_chiplets: graph.num_chiplets,
        classes: graph.single_shot.chiplets_per_class.clone(),
        bottleneck_stage,
        bottleneck_service_ns,
        bottleneck_qps: graph.bottleneck_qps(),
        single_pass_ns: graph.single_pass_ns(),
        single_shot_latency_ns: graph.single_shot.total.latency_ns,
        single_shot_energy_pj: graph.single_shot.total.energy_pj,
        requests: stats.offered,
        completed,
        dropped: stats.dropped,
        throughput_qps: stats.steady_throughput_qps(),
        p50_ms: percentile(&sorted, 50.0) / 1.0e6,
        p95_ms: percentile(&sorted, 95.0) / 1.0e6,
        p99_ms: percentile(&sorted, 99.0) / 1.0e6,
        mean_ms: mean_ns / 1.0e6,
        chiplet_utilization: util,
        mean_utilization,
        peak_utilization,
        energy_per_inference_pj: graph.dynamic_energy_pj + leak_share_pj,
        qos_p99_target_ms: sc.qos_p99_ms,
        weight_load: graph.weight_load,
        wall_seconds: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::simulate;

    fn quick(cfg: SiamConfig) -> SiamConfig {
        cfg.with_serve_requests(256)
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 95.0), 95.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
    }

    #[test]
    fn closed_loop_concurrency_one_matches_single_shot() {
        // the acceptance calibration: at concurrency 1 the pipeline
        // degenerates to sequential inference, so delivered throughput
        // is the single-inference latency reciprocal (within the tiny
        // ingress-fetch share)
        let cfg = quick(SiamConfig::paper_default().with_serve_closed(1));
        let rep = serve(&cfg).unwrap();
        let single = simulate(&cfg).unwrap();
        let want = 1.0e9 / single.total.latency_ns;
        let rel = (rep.throughput_qps - want).abs() / want;
        assert!(rel < 0.01, "closed-1 qps {} vs 1/latency {want} (rel {rel})", rep.throughput_qps);
        assert_eq!(rep.dropped, 0);
        assert_eq!(rep.completed, 256);
        // no queueing at concurrency 1: the tail is flat (p50 and p99
        // agree to float accumulation noise)
        assert!((rep.p99_ms - rep.p50_ms).abs() / rep.p50_ms < 1e-9);
    }

    #[test]
    fn pipelining_beats_sequential_throughput() {
        // deeper concurrency fills the layer pipeline: throughput rises
        // toward the bottleneck ceiling while staying below it
        let base = quick(SiamConfig::paper_default());
        let c1 = serve(&base.clone().with_serve_closed(1)).unwrap();
        let c8 = serve(&base.clone().with_serve_closed(8)).unwrap();
        assert!(
            c8.throughput_qps > 2.0 * c1.throughput_qps,
            "pipelining {} vs sequential {}",
            c8.throughput_qps,
            c1.throughput_qps
        );
        assert!(c8.throughput_qps <= c8.bottleneck_qps * (1.0 + 1e-9));
        assert!(c8.mean_utilization > c1.mean_utilization);
    }

    #[test]
    fn open_loop_saturation_plateaus_at_bottleneck() {
        let base = quick(SiamConfig::paper_default());
        let probe = serve(&base.clone().with_serve_closed(1)).unwrap();
        let cap = probe.bottleneck_qps;
        let over = serve(&base.clone().with_serve_open(2.0 * cap)).unwrap();
        let rel = (over.throughput_qps - cap).abs() / cap;
        assert!(rel < 0.05, "delivered {} vs ceiling {cap} (rel {rel})", over.throughput_qps);
        assert!(over.dropped > 0, "2x overload must shed");
        // below saturation: delivered tracks offered (the post-warm-up
        // window of a finite Poisson sample is noisy — allow 25 %),
        // nothing is shed, and the ceiling is respected
        let under = serve(&base.with_serve_open(0.4 * cap)).unwrap();
        assert_eq!(under.dropped, 0);
        assert!(under.throughput_qps < cap);
        let rel = (under.throughput_qps - under.offered_qps).abs() / under.offered_qps;
        assert!(rel < 0.25, "delivered {} vs offered {}", under.throughput_qps, under.offered_qps);
    }

    #[test]
    fn seed_determinism_bitwise() {
        let cfg = quick(SiamConfig::paper_default().with_serve_open(0.0));
        let a = serve(&cfg).unwrap();
        let b = serve(&cfg).unwrap();
        assert_eq!(a.p50_ms.to_bits(), b.p50_ms.to_bits());
        assert_eq!(a.p95_ms.to_bits(), b.p95_ms.to_bits());
        assert_eq!(a.p99_ms.to_bits(), b.p99_ms.to_bits());
        assert_eq!(a.throughput_qps.to_bits(), b.throughput_qps.to_bits());
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
    }

    #[test]
    fn report_json_renders_and_parses() {
        let cfg = quick(SiamConfig::paper_default().with_model("lenet5", "cifar10"));
        let rep = serve(&cfg).unwrap();
        let s = rep.summary();
        assert!(s.contains("lenet5"));
        assert!(s.contains("p99"));
        let j = rep.to_json().to_string_pretty();
        let back = crate::util::json::parse(&j).expect("serve JSON parses");
        assert_eq!(back.get("mode").and_then(|v| v.as_str()), Some("open"));
        assert!(back.get("p99_ms").and_then(|v| v.as_f64()).is_some());
    }

    #[test]
    fn qos_scoring_tiers() {
        let cfg = quick(SiamConfig::paper_default().with_model("lenet5", "cifar10"));
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.qos_p99_target_ms, cfg.serve.qos_p99_ms);
        let mut meets = rep.clone();
        meets.qos_p99_target_ms = meets.p99_ms + 1.0;
        meets.dropped = 0;
        let mut miss = rep.clone();
        miss.qos_p99_target_ms = miss.p99_ms / 2.0;
        miss.dropped = 0;
        let mut shed = miss.clone();
        shed.dropped = shed.requests / 2;
        assert!(meets.meets_qos());
        assert!(!miss.meets_qos() && !shed.meets_qos());
        // tiered ranking: met target < missed target < shedding
        assert!(meets.qos_score_ms() < miss.qos_score_ms());
        assert!(miss.qos_score_ms() < shed.qos_score_ms());
        // the tiers are strict: even a single shed request with a fast
        // tail ranks after a clean run that merely misses the target
        let mut shed_tiny = meets.clone();
        shed_tiny.dropped = 1;
        assert!(!shed_tiny.meets_qos());
        assert!(shed_tiny.qos_score_ms() > miss.qos_score_ms());
    }

    #[test]
    fn utilization_is_sane() {
        let cfg = quick(SiamConfig::paper_default().with_serve_closed(8));
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.chiplet_utilization.len(), rep.num_chiplets);
        assert!(rep.peak_utilization > 0.0);
        assert!(
            rep.chiplet_utilization.iter().all(|&u| (0.0..=1.0 + 1e-9).contains(&u)),
            "utilization out of range: {:?}",
            rep.chiplet_utilization
        );
    }

    #[test]
    fn monolithic_serving_reports_real_utilization() {
        // monolithic mapping advertises unbounded chiplet capacity; the
        // stage graph must fall back to the mapped crossbars so the
        // single die does not report ~0% utilization
        let cfg = quick(
            SiamConfig::paper_default()
                .with_chip_mode(crate::config::ChipMode::Monolithic)
                .with_serve_closed(8),
        );
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.num_chiplets, 1);
        assert!(
            rep.peak_utilization > 0.01,
            "monolithic utilization collapsed: {}",
            rep.peak_utilization
        );
        assert!(rep.peak_utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn load_amortizes_leakage_energy() {
        // under pipelined load the leakage window per inference shrinks,
        // so energy/inference under load undercuts the single-shot figure
        let cfg = quick(SiamConfig::paper_default().with_serve_closed(8));
        let rep = serve(&cfg).unwrap();
        assert!(rep.energy_per_inference_pj > 0.0);
        assert!(
            rep.energy_per_inference_pj < 2.0 * rep.single_shot_energy_pj,
            "loaded {} vs single-shot {}",
            rep.energy_per_inference_pj,
            rep.single_shot_energy_pj
        );
    }
}
