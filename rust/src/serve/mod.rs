//! Inference-serving simulator: a deterministic discrete-event engine
//! that streams a workload of inference requests through the chiplet
//! system (the ROADMAP's "serve heavy traffic" scenario, which
//! single-shot latency cannot represent).
//!
//! Weight-stationary IMC pins each layer to its chiplet partition, so
//! successive requests pipeline across layer stages. [`stage`] turns a
//! mapped design point into that pipeline (per-stage service times from
//! the circuit / NoC / NoP / DRAM engines, through the shared sweep
//! caches); [`engine`] runs requests through it with bounded per-stage
//! queues and blocking back-pressure; [`traffic`] generates open-loop
//! Poisson arrivals from a seeded splitmix64 stream (closed-loop
//! traffic is self-clocked). The result is a
//! [`ServeReport`](crate::coordinator::ServeReport): throughput,
//! p50/p95/p99 latency, per-chiplet utilization and energy-per-inference
//! under load.
//!
//! Calibration invariants (asserted by tests and the `serve_saturation`
//! bench):
//!
//! * closed-loop concurrency 1 throughput = 1 / single-inference
//!   latency (within the ingress-fetch share, « 1 %);
//! * open-loop throughput plateaus at the analytic bottleneck-stage
//!   service rate once offered load exceeds it;
//! * fixed seed ⇒ bit-identical percentiles, on any machine and under
//!   any sweep thread count.

pub mod decode;
pub mod engine;
pub mod stage;
pub mod traffic;

pub use decode::{
    evaluate_decode, evaluate_decode_traced, serve_decode, serve_decode_traced, DecodeModel,
    DecodeReport, DecodeTracer,
};
pub use engine::{
    run, run_observed, run_with_failover, EngineParams, EngineSink, FailoverPlan, NoopSink,
    RunStats, Workload,
};
pub use stage::{StageGraph, StageSpec};
pub use traffic::{poisson_arrivals, SplitMix64};

use crate::config::{ServeConfig, ServeMode, SiamConfig};
use crate::coordinator::{FailoverReport, ServeReport, SweepContext};
use crate::obs::{CacheSnapshot, RunMeta, TraceBuffer};
use crate::util::json::Json;
use anyhow::Result;

/// `pid` of the serving process in emitted Chrome traces.
const TRACE_PID_SERVE: u32 = 1;

/// An [`EngineSink`] that renders the serving engine's event stream
/// into a Chrome [`TraceBuffer`] — the implementation behind
/// `siam serve --trace`.
///
/// Track layout: process `pid = 1` ("serve"); `tid 0` carries the
/// request lifecycle (admit / queue-wait / shed / complete instants and
/// fail / resume markers); `tid j + 1` carries stage `j`'s occupancy —
/// one `"X"` span per service and per blocking-after-service stall.
/// All timestamps are simulated nanoseconds, so two traced runs of the
/// same `(config, seed)` render byte-identical streams.
#[derive(Debug)]
pub struct ServeTracer {
    buf: TraceBuffer,
    /// Per-stage service start time of the in-flight request.
    serve_start_ns: Vec<f64>,
    /// Per-stage timestamp the current blocking stall began.
    blocked_since_ns: Vec<f64>,
}

fn req_args(req: u32) -> Json {
    let mut a = Json::obj();
    a.set("req", req as u64);
    a
}

impl ServeTracer {
    /// A tracer for `graph`, with the process and per-stage thread
    /// tracks pre-named after the pipeline's layers.
    pub fn new(graph: &StageGraph) -> ServeTracer {
        let mut buf = TraceBuffer::new();
        buf.process_name(TRACE_PID_SERVE, "serve");
        buf.thread_name(TRACE_PID_SERVE, 0, "requests");
        for (j, s) in graph.stages.iter().enumerate() {
            buf.thread_name(TRACE_PID_SERVE, j as u32 + 1, &format!("stage {j}: {}", s.name));
        }
        let n = graph.stages.len();
        ServeTracer {
            buf,
            serve_start_ns: vec![0.0; n],
            blocked_since_ns: vec![0.0; n],
        }
    }

    /// The finished trace buffer.
    pub fn into_buffer(self) -> TraceBuffer {
        self.buf
    }
}

impl EngineSink for ServeTracer {
    fn admitted(&mut self, t_ns: f64, req: u32) {
        self.buf.instant("admit", t_ns, TRACE_PID_SERVE, 0, req_args(req));
    }
    fn queued(&mut self, t_ns: f64, req: u32) {
        self.buf.instant("queue-wait", t_ns, TRACE_PID_SERVE, 0, req_args(req));
    }
    fn shed(&mut self, t_ns: f64, req: u32) {
        self.buf.instant("shed", t_ns, TRACE_PID_SERVE, 0, req_args(req));
    }
    fn serve_start(&mut self, t_ns: f64, stage: usize, _req: u32) {
        self.serve_start_ns[stage] = t_ns;
    }
    fn serve_end(&mut self, t_ns: f64, stage: usize, req: u32) {
        let start = self.serve_start_ns[stage];
        self.buf.complete(
            "serve",
            start,
            t_ns - start,
            TRACE_PID_SERVE,
            stage as u32 + 1,
            req_args(req),
        );
    }
    fn blocked(&mut self, t_ns: f64, stage: usize, _req: u32) {
        self.blocked_since_ns[stage] = t_ns;
    }
    fn unblocked(&mut self, t_ns: f64, stage: usize, req: u32) {
        let start = self.blocked_since_ns[stage];
        self.buf.complete(
            "blocked",
            start,
            t_ns - start,
            TRACE_PID_SERVE,
            stage as u32 + 1,
            req_args(req),
        );
    }
    fn completed(&mut self, t_ns: f64, req: u32, latency_ns: f64) {
        let mut a = req_args(req);
        a.set("latency_ns", latency_ns);
        self.buf.instant("complete", t_ns, TRACE_PID_SERVE, 0, a);
    }
    fn failed(&mut self, t_ns: f64, dead_stages: &[usize], shed: usize) {
        let mut a = Json::obj();
        a.set("dead_stages", dead_stages.len() as u64).set("shed", shed as u64);
        self.buf.instant("fail", t_ns, TRACE_PID_SERVE, 0, a);
    }
    fn resumed(&mut self, t_ns: f64) {
        self.buf.instant("resume", t_ns, TRACE_PID_SERVE, 0, Json::Null);
    }
}

/// Nearest-rank percentile of an **ascending-sorted** latency slice.
/// Returns 0 for an empty slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Run the serving simulator for one configuration, building a fresh
/// [`SweepContext`]. Sweeping many points this way wastes the shared
/// caches — use [`evaluate`] against a shared context instead.
///
/// A `[sweep] cache_file` on the config is honored: known epochs are
/// hydrated before the run and fresh ones persisted after it, so a
/// serve run warms (and is warmed by) sweeps of the same design.
pub fn serve(cfg: &SiamConfig) -> Result<ServeReport> {
    let ctx = SweepContext::new(cfg)?;
    let store = open_store(cfg, &ctx)?;
    let report = evaluate(cfg, &ctx)?;
    if let Some(s) = &store {
        s.absorb(ctx.epoch_cache())?;
    }
    Ok(report)
}

/// Open the config's persistent epoch cache (if any) and hydrate the
/// context's in-memory cache from it.
fn open_store(cfg: &SiamConfig, ctx: &SweepContext) -> Result<Option<crate::noc::EpochStore>> {
    match &cfg.sweep.cache_file {
        Some(path) => {
            let (s, _) = crate::noc::EpochStore::open(path)?;
            s.hydrate(ctx.epoch_cache());
            Ok(Some(s))
        }
        None => Ok(None),
    }
}

/// [`serve`] with the engine's event stream rendered into a Chrome
/// trace (`siam serve --trace`). The report is bit-identical to
/// [`serve`]'s.
pub fn serve_traced(cfg: &SiamConfig) -> Result<(ServeReport, TraceBuffer)> {
    let ctx = SweepContext::new(cfg)?;
    let store = open_store(cfg, &ctx)?;
    let out = evaluate_traced(cfg, &ctx)?;
    if let Some(s) = &store {
        s.absorb(ctx.epoch_cache())?;
    }
    Ok(out)
}

/// Run the serving simulator for one configuration against a shared
/// sweep context: the stage service times come out of the context's
/// layer-cost / epoch / DRAM caches, so a point the sweep already
/// simulated costs only the event loop.
pub fn evaluate(cfg: &SiamConfig, ctx: &SweepContext) -> Result<ServeReport> {
    let t0 = std::time::Instant::now();
    let graph = StageGraph::build(cfg, ctx)?;
    evaluate_graph(cfg, ctx, &graph, &mut NoopSink, t0)
}

/// [`evaluate`] with the engine's event stream rendered into a Chrome
/// trace (see [`ServeTracer`]) — the entry point behind
/// `siam serve --trace`. The report is bit-identical to [`evaluate`]'s.
pub fn evaluate_traced(cfg: &SiamConfig, ctx: &SweepContext) -> Result<(ServeReport, TraceBuffer)> {
    let t0 = std::time::Instant::now();
    let graph = StageGraph::build(cfg, ctx)?;
    let mut tracer = ServeTracer::new(&graph);
    let report = evaluate_graph(cfg, ctx, &graph, &mut tracer, t0)?;
    Ok((report, tracer.into_buffer()))
}

/// Shared tail of [`evaluate`] / [`evaluate_traced`]: run the engine
/// against the prebuilt graph with `sink` observing, then attach the
/// run's `meta` block (config fingerprint, seeds, model source,
/// wall-clock, epoch-cache snapshot and engine-tier tally).
fn evaluate_graph<S: EngineSink>(
    cfg: &SiamConfig,
    ctx: &SweepContext,
    graph: &StageGraph,
    sink: &mut S,
    t0: std::time::Instant,
) -> Result<ServeReport> {
    let mut report = if cfg.serve.fail_at_request.is_some() {
        run_failover_graph(cfg, graph, ctx, sink)?
    } else {
        run_graph_sink(graph, &cfg.serve, sink)
    };
    let mut meta = RunMeta::for_config(cfg);
    meta.model_source = graph.single_shot.model_source.clone();
    meta.epoch_cache = Some(CacheSnapshot::capture(ctx.epoch_cache()));
    meta.engine_tiers = Some(graph.single_shot.engine_tiers);
    meta.wall_seconds = t0.elapsed().as_secs_f64();
    report.meta = Some(meta);
    Ok(report)
}

/// Run the serving engine on a prebuilt [`StageGraph`] — the QoS sweep
/// builds each point's graph once (it carries the single-shot report
/// too) and calls this, so QoS ranking adds only the event loop.
pub fn run_graph(graph: &StageGraph, sc: &ServeConfig) -> ServeReport {
    run_graph_sink(graph, sc, &mut NoopSink)
}

/// [`run_graph`] with an [`EngineSink`] observing the engine's event
/// stream. The sink is a pure observer; the report is bit-identical to
/// [`run_graph`]'s.
pub fn run_graph_sink<S: EngineSink>(
    graph: &StageGraph,
    sc: &ServeConfig,
    sink: &mut S,
) -> ServeReport {
    let t0 = std::time::Instant::now();
    // periodic drift-refresh maintenance steals a duty-cycle fraction
    // of every stage's service time; scale 1.0 (no variation, or no
    // refresh) leaves the services bit-identical
    let scale = graph.variation.as_ref().map_or(1.0, |v| v.service_scale());
    let services: Vec<f64> = graph.stages.iter().map(|s| s.service_ns * scale).collect();
    let (workload, mode, offered_qps, concurrency) = match sc.mode {
        ServeMode::Open => {
            let rate = open_rate_qps(graph, sc);
            (
                Workload::Open {
                    arrivals: poisson_arrivals(rate, sc.requests, sc.seed),
                },
                "open",
                rate,
                0,
            )
        }
        ServeMode::Closed => (
            Workload::Closed { concurrency: sc.concurrency, requests: sc.requests },
            "closed",
            0.0,
            sc.concurrency,
        ),
    };

    let stats =
        run_observed(&services, EngineParams { queue_depth: sc.queue_depth }, workload, None, sink);
    assemble_report(graph, sc, stats, mode, offered_qps, concurrency, t0)
}

/// The open-loop offered rate of a serving run: the configured
/// `[serve] rate_qps`, or 80 % of the analytic bottleneck ceiling when
/// auto (0) — loaded but stable.
fn open_rate_qps(graph: &StageGraph, sc: &ServeConfig) -> f64 {
    if sc.rate_qps > 0.0 {
        sc.rate_qps
    } else {
        0.8 * graph.bottleneck_qps()
    }
}

/// Turn raw engine statistics into a [`ServeReport`] (shared by the
/// healthy and failover paths — identical float operations in
/// identical order, so the zero-fault path stays bit-identical).
fn assemble_report(
    graph: &StageGraph,
    sc: &ServeConfig,
    stats: RunStats,
    mode: &str,
    offered_qps: f64,
    concurrency: usize,
    t0: std::time::Instant,
) -> ServeReport {
    let mut sorted = stats.latencies_ns.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mean_ns = if sorted.is_empty() {
        0.0
    } else {
        sorted.iter().sum::<f64>() / sorted.len() as f64
    };

    // crossbar-weighted per-chiplet busy fraction over the window
    // (per-chiplet capacity denominators — classes differ in size)
    let window_ns = stats.window_ns().max(1e-9);
    let mut util = vec![0.0f64; graph.num_chiplets];
    for (spec, &busy) in graph.stages.iter().zip(&stats.stage_busy_ns) {
        for &(c, xbars) in &spec.shares {
            let cap = graph.chiplet_capacities_xbars[c].max(1) as f64;
            util[c] += busy * xbars as f64 / (cap * window_ns);
        }
    }
    let mean_utilization = if util.is_empty() {
        0.0
    } else {
        util.iter().sum::<f64>() / util.len() as f64
    };
    let peak_utilization = util.iter().copied().fold(0.0f64, f64::max);

    let completed = stats.completed;
    let leak_share_pj = if completed > 0 {
        graph.leakage_uw * stats.window_ns() / completed as f64 / 1.0e3
    } else {
        0.0
    };
    let (bottleneck_stage, bottleneck_service_ns) = graph.bottleneck();

    ServeReport {
        model: graph.single_shot.model.clone(),
        dataset: graph.single_shot.dataset.clone(),
        model_source: graph.single_shot.model_source.clone(),
        mode: mode.into(),
        offered_qps,
        concurrency,
        num_stages: graph.stages.len(),
        num_chiplets: graph.num_chiplets,
        classes: graph.single_shot.chiplets_per_class.clone(),
        bottleneck_stage,
        bottleneck_service_ns,
        bottleneck_qps: graph.bottleneck_qps(),
        single_pass_ns: graph.single_pass_ns(),
        single_shot_latency_ns: graph.single_shot.total.latency_ns,
        single_shot_energy_pj: graph.single_shot.total.energy_pj,
        requests: stats.offered,
        completed,
        dropped: stats.dropped,
        throughput_qps: stats.steady_throughput_qps(),
        p50_ms: percentile(&sorted, 50.0) / 1.0e6,
        p95_ms: percentile(&sorted, 95.0) / 1.0e6,
        p99_ms: percentile(&sorted, 99.0) / 1.0e6,
        mean_ms: mean_ns / 1.0e6,
        chiplet_utilization: util,
        mean_utilization,
        peak_utilization,
        energy_per_inference_pj: graph.dynamic_energy_pj + leak_share_pj,
        qos_p99_target_ms: sc.qos_p99_ms,
        weight_load: graph.weight_load,
        failover: None,
        decode: None,
        variation: graph.variation.clone(),
        wall_seconds: t0.elapsed().as_secs_f64(),
        meta: None,
    }
}

/// Run the mid-run chiplet-failure scenario (`[serve]
/// fail_at_request`): the healthy pipeline streams open-loop traffic,
/// `fail_chiplet` dies at the configured request's arrival, and — when
/// the DNN remaps onto the surviving capacity (spares included) — the
/// degraded pipeline hot-swaps in after `remap_latency_us`. The
/// returned report carries a [`FailoverReport`] with the shed counts
/// and the before/during/after tail latency.
fn run_failover_graph<S: EngineSink>(
    cfg: &SiamConfig,
    graph: &StageGraph,
    ctx: &SweepContext,
    sink: &mut S,
) -> Result<ServeReport> {
    let t0 = std::time::Instant::now();
    let sc = &cfg.serve;
    let fail_at = sc.fail_at_request.expect("caller checked fail_at_request");
    anyhow::ensure!(
        fail_at < sc.requests,
        "serve.fail_at_request = {fail_at} is outside the {} offered requests",
        sc.requests
    );
    anyhow::ensure!(
        sc.fail_chiplet < graph.num_chiplets,
        "serve.fail_chiplet = {} but the architecture has {} chiplets (spares included)",
        sc.fail_chiplet,
        graph.num_chiplets
    );

    let rate = open_rate_qps(graph, sc);
    let arrivals = poisson_arrivals(rate, sc.requests, sc.seed);
    let fail_time_ns = arrivals[fail_at];
    let dead_stages: Vec<usize> = graph
        .stages
        .iter()
        .enumerate()
        .filter(|(_, s)| s.shares.iter().any(|&(c, _)| c == sc.fail_chiplet))
        .map(|(j, _)| j)
        .collect();

    // the remapped pipeline: the same design point with the failed
    // chiplet added to the kill list, rebuilt through the shared
    // caches (spare capacity absorbs the dead chiplet's layers — or
    // the build errors, and the outage never ends)
    let mut degraded = cfg.clone();
    degraded.serve.fail_at_request = None;
    if !degraded.fault.kill_chiplets.contains(&sc.fail_chiplet) {
        degraded.fault.kill_chiplets.push(sc.fail_chiplet);
    }
    let (resume, remap_error) = match StageGraph::build(&degraded, ctx) {
        Ok(g) => {
            let services: Vec<f64> = g.stages.iter().map(|s| s.service_ns).collect();
            (Some((fail_time_ns + sc.remap_latency_us * 1.0e3, services)), None)
        }
        Err(e) => (None, Some(format!("{e:#}"))),
    };
    let resume_time_ns = resume.as_ref().map(|(t, _)| *t);

    let plan = FailoverPlan { fail_time_ns, dead_stages: dead_stages.clone(), resume };
    let stats = run_observed(
        &graph.stages.iter().map(|s| s.service_ns).collect::<Vec<_>>(),
        EngineParams { queue_depth: sc.queue_depth },
        Workload::Open { arrivals },
        Some(&plan),
        sink,
    );

    // windowed tails: completions before the failure, inside the
    // outage, and on the remapped pipeline
    let (mut before, mut during, mut after) = (Vec::new(), Vec::new(), Vec::new());
    let mut first_after_ns = f64::INFINITY;
    for (&t, &l) in stats.completion_times_ns.iter().zip(&stats.latencies_ns) {
        if t < fail_time_ns {
            before.push(l);
        } else if resume_time_ns.is_none_or(|rt| t < rt) {
            during.push(l);
        } else {
            first_after_ns = first_after_ns.min(t);
            after.push(l);
        }
    }
    for w in [&mut before, &mut during, &mut after] {
        w.sort_by(|a, b| a.total_cmp(b));
    }
    let recovered = !after.is_empty();
    let failover = FailoverReport {
        fail_chiplet: sc.fail_chiplet,
        fail_time_ms: fail_time_ns / 1.0e6,
        remap_latency_ms: sc.remap_latency_us / 1.0e3,
        dead_stages: dead_stages.len(),
        recovered,
        recovery_ms: if recovered { (first_after_ns - fail_time_ns) / 1.0e6 } else { 0.0 },
        shed_total: stats.failover_shed + stats.dropped,
        shed_in_flight: stats.failover_shed,
        p99_before_ms: percentile(&before, 99.0) / 1.0e6,
        p99_during_ms: percentile(&during, 99.0) / 1.0e6,
        p99_after_ms: percentile(&after, 99.0) / 1.0e6,
        spare_chiplets: cfg.system.spare_chiplets,
        remap_error,
    };

    let mut report = assemble_report(graph, sc, stats, "open", rate, 0, t0);
    report.failover = Some(failover);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::simulate;

    fn quick(cfg: SiamConfig) -> SiamConfig {
        cfg.with_serve_requests(256)
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 95.0), 95.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
    }

    #[test]
    fn closed_loop_concurrency_one_matches_single_shot() {
        // the acceptance calibration: at concurrency 1 the pipeline
        // degenerates to sequential inference, so delivered throughput
        // is the single-inference latency reciprocal (within the tiny
        // ingress-fetch share)
        let cfg = quick(SiamConfig::paper_default().with_serve_closed(1));
        let rep = serve(&cfg).unwrap();
        let single = simulate(&cfg).unwrap();
        let want = 1.0e9 / single.total.latency_ns;
        let rel = (rep.throughput_qps - want).abs() / want;
        assert!(rel < 0.01, "closed-1 qps {} vs 1/latency {want} (rel {rel})", rep.throughput_qps);
        assert_eq!(rep.dropped, 0);
        assert_eq!(rep.completed, 256);
        // no queueing at concurrency 1: the tail is flat (p50 and p99
        // agree to float accumulation noise)
        assert!((rep.p99_ms - rep.p50_ms).abs() / rep.p50_ms < 1e-9);
    }

    #[test]
    fn pipelining_beats_sequential_throughput() {
        // deeper concurrency fills the layer pipeline: throughput rises
        // toward the bottleneck ceiling while staying below it
        let base = quick(SiamConfig::paper_default());
        let c1 = serve(&base.clone().with_serve_closed(1)).unwrap();
        let c8 = serve(&base.clone().with_serve_closed(8)).unwrap();
        assert!(
            c8.throughput_qps > 2.0 * c1.throughput_qps,
            "pipelining {} vs sequential {}",
            c8.throughput_qps,
            c1.throughput_qps
        );
        assert!(c8.throughput_qps <= c8.bottleneck_qps * (1.0 + 1e-9));
        assert!(c8.mean_utilization > c1.mean_utilization);
    }

    #[test]
    fn open_loop_saturation_plateaus_at_bottleneck() {
        let base = quick(SiamConfig::paper_default());
        let probe = serve(&base.clone().with_serve_closed(1)).unwrap();
        let cap = probe.bottleneck_qps;
        let over = serve(&base.clone().with_serve_open(2.0 * cap)).unwrap();
        let rel = (over.throughput_qps - cap).abs() / cap;
        assert!(rel < 0.05, "delivered {} vs ceiling {cap} (rel {rel})", over.throughput_qps);
        assert!(over.dropped > 0, "2x overload must shed");
        // below saturation: delivered tracks offered (the post-warm-up
        // window of a finite Poisson sample is noisy — allow 25 %),
        // nothing is shed, and the ceiling is respected
        let under = serve(&base.with_serve_open(0.4 * cap)).unwrap();
        assert_eq!(under.dropped, 0);
        assert!(under.throughput_qps < cap);
        let rel = (under.throughput_qps - under.offered_qps).abs() / under.offered_qps;
        assert!(rel < 0.25, "delivered {} vs offered {}", under.throughput_qps, under.offered_qps);
    }

    #[test]
    fn seed_determinism_bitwise() {
        let cfg = quick(SiamConfig::paper_default().with_serve_open(0.0));
        let a = serve(&cfg).unwrap();
        let b = serve(&cfg).unwrap();
        assert_eq!(a.p50_ms.to_bits(), b.p50_ms.to_bits());
        assert_eq!(a.p95_ms.to_bits(), b.p95_ms.to_bits());
        assert_eq!(a.p99_ms.to_bits(), b.p99_ms.to_bits());
        assert_eq!(a.throughput_qps.to_bits(), b.throughput_qps.to_bits());
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
    }

    #[test]
    fn report_json_renders_and_parses() {
        let cfg = quick(SiamConfig::paper_default().with_model("lenet5", "cifar10"));
        let rep = serve(&cfg).unwrap();
        let s = rep.summary();
        assert!(s.contains("lenet5"));
        assert!(s.contains("p99"));
        let j = rep.to_json().to_string_pretty();
        let back = crate::util::json::parse(&j).expect("serve JSON parses");
        assert_eq!(back.get("mode").and_then(|v| v.as_str()), Some("open"));
        assert!(back.get("p99_ms").and_then(|v| v.as_f64()).is_some());
    }

    #[test]
    fn qos_scoring_tiers() {
        let cfg = quick(SiamConfig::paper_default().with_model("lenet5", "cifar10"));
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.qos_p99_target_ms, cfg.serve.qos_p99_ms);
        let mut meets = rep.clone();
        meets.qos_p99_target_ms = meets.p99_ms + 1.0;
        meets.dropped = 0;
        let mut miss = rep.clone();
        miss.qos_p99_target_ms = miss.p99_ms / 2.0;
        miss.dropped = 0;
        let mut shed = miss.clone();
        shed.dropped = shed.requests / 2;
        assert!(meets.meets_qos());
        assert!(!miss.meets_qos() && !shed.meets_qos());
        // tiered ranking: met target < missed target < shedding
        assert!(meets.qos_score_ms() < miss.qos_score_ms());
        assert!(miss.qos_score_ms() < shed.qos_score_ms());
        // the tiers are strict: even a single shed request with a fast
        // tail ranks after a clean run that merely misses the target
        let mut shed_tiny = meets.clone();
        shed_tiny.dropped = 1;
        assert!(!shed_tiny.meets_qos());
        assert!(shed_tiny.qos_score_ms() > miss.qos_score_ms());
    }

    #[test]
    fn failover_spare_vs_no_spare() {
        // the acceptance scenario: chiplet 3 dies at request 64. With a
        // spare the system remaps and recovers after the remap latency;
        // without one the dead chiplet's layers have nowhere to go, the
        // pipeline jams, and the rest of the stream sheds.
        let base = quick(SiamConfig::paper_default().with_serve_open(0.0))
            .with_failover(64, 3, 50.0);
        let no_spare = serve(&base).unwrap();
        let spared = serve(&base.clone().with_spare_chiplets(1)).unwrap();

        let fs = spared.failover.as_ref().expect("failover report attached");
        assert!(fs.recovered, "spare must absorb the dead chiplet: {:?}", fs.remap_error);
        assert!(fs.remap_error.is_none());
        assert_eq!(fs.fail_chiplet, 3);
        assert_eq!(fs.spare_chiplets, 1);
        assert!(fs.dead_stages > 0, "chiplet 3 hosts early layers");
        // recovery is measured to the first remapped completion, so it
        // is at least the configured remap latency
        assert!(fs.recovery_ms >= fs.remap_latency_ms - 1e-9, "{}", fs.recovery_ms);
        assert!(fs.p99_before_ms > 0.0 && fs.p99_after_ms > 0.0);

        let fx = no_spare.failover.as_ref().expect("failover report attached");
        assert!(!fx.recovered, "a fully packed system cannot remap without spares");
        assert!(fx.remap_error.is_some());
        // the headline: spares shed strictly less on the same seed
        assert!(
            fs.shed_total < fx.shed_total,
            "spare shed {} vs no-spare shed {}",
            fs.shed_total,
            fx.shed_total
        );
        assert!(spared.completed > no_spare.completed);

        // the failover block rides into JSON and the summary
        let j = spared.to_json().to_string_pretty();
        assert!(j.contains("\"failover\"") && j.contains("\"recovery_ms\""));
        let back = crate::util::json::parse(&j).expect("failover JSON parses");
        let f = back.get("failover").expect("failover key");
        assert_eq!(f.get("recovered"), Some(&crate::util::json::Json::Bool(true)));
        assert!(spared.summary().contains("failover: chiplet 3"));
    }

    #[test]
    fn failover_is_bit_deterministic() {
        let cfg = quick(SiamConfig::paper_default().with_serve_open(0.0))
            .with_spare_chiplets(1)
            .with_failover(64, 3, 50.0);
        let a = serve(&cfg).unwrap();
        let b = serve(&cfg).unwrap();
        assert_eq!(a.p99_ms.to_bits(), b.p99_ms.to_bits());
        assert_eq!(a.completed, b.completed);
        let (fa, fb) = (a.failover.as_ref().unwrap(), b.failover.as_ref().unwrap());
        assert_eq!(fa.shed_total, fb.shed_total);
        assert_eq!(fa.recovery_ms.to_bits(), fb.recovery_ms.to_bits());
        assert_eq!(fa.p99_during_ms.to_bits(), fb.p99_during_ms.to_bits());
    }

    #[test]
    fn utilization_is_sane() {
        let cfg = quick(SiamConfig::paper_default().with_serve_closed(8));
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.chiplet_utilization.len(), rep.num_chiplets);
        assert!(rep.peak_utilization > 0.0);
        assert!(
            rep.chiplet_utilization.iter().all(|&u| (0.0..=1.0 + 1e-9).contains(&u)),
            "utilization out of range: {:?}",
            rep.chiplet_utilization
        );
    }

    #[test]
    fn monolithic_serving_reports_real_utilization() {
        // monolithic mapping advertises unbounded chiplet capacity; the
        // stage graph must fall back to the mapped crossbars so the
        // single die does not report ~0% utilization
        let cfg = quick(
            SiamConfig::paper_default()
                .with_chip_mode(crate::config::ChipMode::Monolithic)
                .with_serve_closed(8),
        );
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.num_chiplets, 1);
        assert!(
            rep.peak_utilization > 0.01,
            "monolithic utilization collapsed: {}",
            rep.peak_utilization
        );
        assert!(rep.peak_utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn load_amortizes_leakage_energy() {
        // under pipelined load the leakage window per inference shrinks,
        // so energy/inference under load undercuts the single-shot figure
        let cfg = quick(SiamConfig::paper_default().with_serve_closed(8));
        let rep = serve(&cfg).unwrap();
        assert!(rep.energy_per_inference_pj > 0.0);
        assert!(
            rep.energy_per_inference_pj < 2.0 * rep.single_shot_energy_pj,
            "loaded {} vs single-shot {}",
            rep.energy_per_inference_pj,
            rep.single_shot_energy_pj
        );
    }
}
