//! Request-traffic generators for the serving simulator: a seeded
//! splitmix64 RNG and the open-loop Poisson arrival process built on it.
//!
//! Closed-loop traffic needs no generator — each of the fixed clients
//! issues its next request the instant the previous one completes, so
//! arrival times emerge from the engine itself.

/// splitmix64 (Steele et al.): a tiny, statistically solid, seedable
/// counter-based generator. Chosen over the crate-wide xorshift64* so
/// the serving workload stream is independent of any other RNG use and
/// reproducible from the `[serve] seed` alone.
#[derive(Debug, Clone)]
pub struct SplitMix64(u64);

impl SplitMix64 {
    /// Generator seeded with `seed` (all seeds are valid, including 0).
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64(seed)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in the half-open interval (0, 1] — the exclusion of 0
    /// keeps `ln(u)` finite for exponential sampling.
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 / (1u64 << 53) as f64
    }

    /// Exponentially distributed sample with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -self.f64_open().ln() * mean
    }
}

/// Open-loop Poisson arrival process: `n` arrival timestamps (ns,
/// ascending, starting at the first interarrival gap) for an offered
/// rate of `rate_qps` inferences/s. Deterministic in `(rate_qps, n,
/// seed)`.
pub fn poisson_arrivals(rate_qps: f64, n: usize, seed: u64) -> Vec<f64> {
    assert!(rate_qps > 0.0, "open-loop arrivals need a positive rate");
    let mean_gap_ns = 1.0e9 / rate_qps;
    let mut rng = SplitMix64::new(seed);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exponential(mean_gap_ns);
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic_and_full_period_start() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // seed 0 is a valid stream distinct from seed 1
        assert_ne!(SplitMix64::new(0).next_u64(), SplitMix64::new(1).next_u64());
    }

    #[test]
    fn f64_open_stays_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let v = r.f64_open();
            assert!(v > 0.0 && v <= 1.0, "{v}");
        }
    }

    #[test]
    fn poisson_mean_gap_matches_rate() {
        let n = 20_000;
        let arr = poisson_arrivals(1000.0, n, 1); // 1000 qps => 1e6 ns mean gap
        assert!(arr.windows(2).all(|w| w[0] < w[1]), "ascending");
        let mean = arr.last().unwrap() / n as f64;
        assert!((mean / 1.0e6 - 1.0).abs() < 0.03, "mean gap {mean} ns");
    }

    #[test]
    fn arrivals_reproducible_by_seed() {
        assert_eq!(
            poisson_arrivals(500.0, 64, 9).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            poisson_arrivals(500.0, 64, 9).iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_ne!(
            poisson_arrivals(500.0, 64, 9)[0].to_bits(),
            poisson_arrivals(500.0, 64, 10)[0].to_bits()
        );
    }
}
