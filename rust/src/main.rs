//! SIAM command-line launcher.
//!
//! ```text
//! siam simulate  [--config F] [--model M --dataset D] [--tiles N]
//!                [--chiplets N] [--monolithic] [--placement P]
//!                [--spares N] [--kill-chiplet 3,7] [--fault-seed S]
//!                [--cache-file PATH] [--trace PATH] [--profile] [--json PATH]
//! siam sweep     [--config F] [--model M --dataset D]
//!                [--tiles 4,9,16,25,36] [--counts 16,36,64,100]
//!                [--placement rowmajor|dataflow] [--fom edap|...|yield|variation]
//!                [--cache-file PATH] [--search exhaustive|pareto|halving]
//!                [--halving-keep 0.5] [--profile] [--json PATH]
//! siam serve     [--config F] [--mode open|closed] [--rate QPS]
//!                [--concurrency N] [--requests N] [--queue N] [--seed S]
//!                [--fail-at N --fail-chiplet C --remap-latency US --spares N]
//!                [--decode] [--max-new-tokens N] [--kv-bits B]
//!                [--batch-cap N] [--prefill-chunk N]
//!                [--quick] [--trace PATH] [--json PATH]
//! siam functional [--artifacts DIR] [--adc 8] [--seed 42]
//! siam models    [--files DIR]
//! siam config    (print the paper-default TOML)
//! ```
//!
//! `--model` accepts a zoo name or a network-description file
//! (`--model file:net.toml`, see `docs/MODELS.md`). Every command
//! accepts `--log-level quiet|normal|verbose`; `--trace` writes a
//! deterministic Chrome trace and `--profile` a host wall-clock stage
//! breakdown (`docs/OBSERVABILITY.md`).
//!
//! Argument parsing is in-tree (the offline build vendors no clap).

use anyhow::{bail, Context, Result};
use siam::config::{ChipMode, PlacementPolicy, ServeMode, SiamConfig};
use siam::coordinator::{self, SweepBuilder};
use siam::obs::{self, LogLevel, Profiler, TraceBuffer};
use siam::util::json::Json;
use siam::util::table::{eng, Table};
use std::collections::HashMap;
use std::sync::Arc;

fn parse_flags(args: &[String]) -> Result<(Vec<String>, HashMap<String, String>)> {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            // boolean flags take no value
            if matches!(name, "monolithic" | "help" | "quick" | "profile" | "decode") {
                flags.insert(name.to_string(), "true".into());
                i += 1;
            } else {
                let v = args
                    .get(i + 1)
                    .with_context(|| format!("--{name} needs a value"))?;
                flags.insert(name.to_string(), v.clone());
                i += 2;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    Ok((pos, flags))
}

fn build_config(flags: &HashMap<String, String>) -> Result<SiamConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => SiamConfig::from_toml_file(path)?,
        None => SiamConfig::paper_default(),
    };
    if let Some(m) = flags.get("model") {
        cfg.dnn.model = m.clone();
    }
    if let Some(d) = flags.get("dataset") {
        cfg.dnn.dataset = d.clone();
    }
    if let Some(t) = flags.get("tiles") {
        cfg.chiplet.tiles_per_chiplet = t.parse().context("--tiles")?;
    }
    if let Some(c) = flags.get("chiplets") {
        cfg = cfg.with_total_chiplets(c.parse().context("--chiplets")?);
    }
    if flags.contains_key("monolithic") {
        cfg.system.chip_mode = ChipMode::Monolithic;
    }
    if let Some(p) = flags.get("placement") {
        cfg.system.placement = match p.as_str() {
            "rowmajor" => PlacementPolicy::RowMajor,
            "dataflow" => PlacementPolicy::Dataflow,
            other => bail!("--placement must be rowmajor|dataflow, got '{other}'"),
        };
    }
    if let Some(s) = flags.get("spares") {
        cfg.system.spare_chiplets = s.parse().context("--spares")?;
    }
    if let Some(k) = flags.get("kill-chiplet") {
        cfg.fault.kill_chiplets = parse_list(k).context("--kill-chiplet")?;
    }
    if let Some(s) = flags.get("fault-seed") {
        cfg.fault.seed = s.parse().context("--fault-seed")?;
    }
    if let Some(path) = flags.get("cache-file") {
        cfg.sweep.cache_file = Some(path.clone());
    }
    if let Some(s) = flags.get("search") {
        use siam::config::SearchMode;
        cfg.sweep.search = match s.as_str() {
            "exhaustive" => SearchMode::Exhaustive,
            "pareto" => SearchMode::Pareto,
            "halving" => SearchMode::Halving,
            other => bail!("--search must be exhaustive|pareto|halving, got '{other}'"),
        };
    }
    if let Some(k) = flags.get("halving-keep") {
        cfg.sweep.halving_keep = k.parse().context("--halving-keep")?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn parse_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|p| p.trim().parse::<usize>().context("bad list element"))
        .collect()
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = build_config(flags)?;
    let ctx = coordinator::SweepContext::new(&cfg)?;
    // --cache-file: hydrate known epochs before the run, persist fresh
    // ones after it (docs/CACHING.md)
    let store = match &cfg.sweep.cache_file {
        Some(path) => {
            let (s, loaded) = siam::noc::EpochStore::open(path)?;
            s.hydrate(ctx.epoch_cache());
            obs::log::verbose(&format!(
                "cache {path}: {} epoch(s) loaded",
                loaded.epochs_loaded
            ));
            Some(s)
        }
        None => None,
    };
    let prof = flags.contains_key("profile").then(Profiler::new);
    let mut trace = flags.get("trace").map(|_| TraceBuffer::new());

    // --trace runs the serial engine path (the timeline is layer-serial
    // anyway) and is bit-identical to the concurrent default
    let mut rep = if let Some(buf) = trace.as_mut() {
        let run = || coordinator::trace_point(&cfg, &ctx, buf);
        match prof.as_ref() {
            Some(p) => p.time("trace:point", run)?,
            None => run()?,
        }
    } else {
        coordinator::run_point_profiled(&cfg, &ctx, true, prof.as_ref())?
    };
    if rep.meta.is_none() {
        coordinator::attach_meta(&cfg, &ctx, &mut rep);
    }
    if let Some(s) = &store {
        s.absorb(ctx.epoch_cache())?;
    }
    println!("{}", rep.summary());
    if let Some(p) = &prof {
        println!("\nself-profile (host wall-clock):");
        println!("{}", p.render_table());
    }
    if let (Some(path), Some(buf)) = (flags.get("trace"), &trace) {
        std::fs::write(path, buf.render())?;
        obs::log::info(&format!("wrote {path} ({} trace events)", buf.len()));
    }
    if let Some(path) = flags.get("json") {
        let mut j = rep.to_json();
        if let Some(p) = &prof {
            j.set("profile", p.to_json());
        }
        std::fs::write(path, j.to_string_pretty())?;
        obs::log::info(&format!("wrote {path}"));
    }
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    // `--tiles` is the sweep-axis list here, not the scalar
    // tiles-per-chiplet override build_config parses for `simulate`
    let mut base_flags = flags.clone();
    base_flags.remove("tiles");
    let cfg = build_config(&base_flags)?;
    let tiles = parse_list(flags.get("tiles").map(String::as_str).unwrap_or("4,9,16,25,36"))?;
    let counts: Vec<Option<usize>> = match flags.get("counts") {
        Some(c) => parse_list(c)?.into_iter().map(Some).chain([None]).collect(),
        None => vec![None],
    };
    let mut builder = SweepBuilder::new(&cfg).tiles(&tiles).chiplet_counts(&counts);
    if let Some(fom) = flags.get("fom") {
        use siam::coordinator::FigureOfMerit;
        builder = builder.figure_of_merit(match fom.as_str() {
            "edap" => FigureOfMerit::Edap,
            "edp" => FigureOfMerit::Edp,
            "energy" => FigureOfMerit::Energy,
            "latency" => FigureOfMerit::Latency,
            "area" => FigureOfMerit::Area,
            "ipj" => FigureOfMerit::InferencesPerJoule,
            "yield" => FigureOfMerit::YieldCost,
            "variation" => FigureOfMerit::VariationAware,
            other => {
                bail!("--fom must be edap|edp|energy|latency|area|ipj|yield|variation, got '{other}'")
            }
        });
    }
    let prof = flags.contains_key("profile").then(|| Arc::new(Profiler::new()));
    if let Some(p) = &prof {
        builder = builder.profile(p.clone());
    }
    let res = builder.run()?;
    let pts = &res.points;
    let mut t = Table::new(&[
        "tiles/chiplet",
        "chiplets",
        "area mm2",
        "energy uJ",
        "latency ms",
        "EDAP",
    ]);
    for p in pts {
        t.row(&[
            p.tiles_per_chiplet.to_string(),
            p.total_chiplets
                .map(|c| c.to_string())
                .unwrap_or_else(|| format!("custom({})", p.report.num_chiplets)),
            eng(p.report.total.area_mm2()),
            eng(p.report.total.energy_uj()),
            eng(p.report.total.latency_ms()),
            format!("{:.3e}", p.report.total.edap()),
        ]);
    }
    t.print();
    let s = &res.stats;
    println!(
        "\nepoch cache: {} hits / {} misses ({:.1}% hit rate), {} epochs cached",
        s.epoch_hits,
        s.epoch_misses,
        100.0 * s.epoch_hit_rate(),
        s.epochs_cached
    );
    if cfg.sweep.cache_file.is_some() {
        println!(
            "persistent cache: {} epochs hydrated from disk, {} of {} points already known",
            s.epochs_hydrated,
            s.points_known,
            pts.len()
        );
    }
    let shard_line: Vec<String> = s.shards.iter().map(|&(h, m)| format!("{h}/{m}")).collect();
    println!("epoch cache shards (hits/misses): {}", shard_line.join("  "));
    println!("engine tiers: {}", s.tiers.render());
    println!("sweep wall-clock: {:.2}s ({:.1} points/s)", s.wall_seconds, s.points_per_sec);
    if let Some(best) = coordinator::dse::best_by_edap(pts) {
        println!(
            "\nEDAP-optimal: {} tiles/chiplet, {} chiplets",
            best.tiles_per_chiplet, best.report.num_chiplets
        );
    }
    if let Some(fom) = flags.get("fom") {
        if let Some(best) = res.best() {
            println!(
                "{fom}-optimal: {} tiles/chiplet, {} chiplets",
                best.tiles_per_chiplet, best.report.num_chiplets
            );
        }
    }
    if let Some(p) = &prof {
        println!("\nself-profile (host wall-clock):");
        println!("{}", p.render_table());
    }
    if let Some(path) = flags.get("json") {
        let mut out = coordinator::report::sweep_json(&cfg, &res);
        if let Some(p) = &prof {
            out.set("profile", p.to_json());
        }
        std::fs::write(path, out.to_string_pretty())?;
        obs::log::info(&format!("wrote {path}"));
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let mut cfg = build_config(flags)?;
    if let Some(m) = flags.get("mode") {
        cfg.serve.mode = match m.as_str() {
            "open" => ServeMode::Open,
            "closed" => ServeMode::Closed,
            other => bail!("--mode must be open|closed, got '{other}'"),
        };
    }
    if let Some(r) = flags.get("rate") {
        cfg.serve.rate_qps = r.parse().context("--rate")?;
    }
    if let Some(c) = flags.get("concurrency") {
        cfg.serve.concurrency = c.parse().context("--concurrency")?;
    }
    if let Some(n) = flags.get("requests") {
        cfg.serve.requests = n.parse().context("--requests")?;
    }
    if let Some(q) = flags.get("queue") {
        cfg.serve.queue_depth = q.parse().context("--queue")?;
    }
    if let Some(s) = flags.get("seed") {
        cfg.serve.seed = s.parse().context("--seed")?;
    }
    // mid-run chiplet-failure scenario (implies open-loop traffic)
    if let Some(n) = flags.get("fail-at") {
        cfg.serve.fail_at_request = Some(n.parse().context("--fail-at")?);
    }
    if let Some(c) = flags.get("fail-chiplet") {
        cfg.serve.fail_chiplet = c.parse().context("--fail-chiplet")?;
    }
    if let Some(us) = flags.get("remap-latency") {
        cfg.serve.remap_latency_us = us.parse().context("--remap-latency")?;
    }
    // autoregressive decode serving ([decode] block overrides)
    if let Some(n) = flags.get("max-new-tokens") {
        cfg.decode.max_new_tokens = n.parse().context("--max-new-tokens")?;
    }
    if let Some(b) = flags.get("kv-bits") {
        cfg.decode.kv_precision_bits = b.parse().context("--kv-bits")?;
    }
    if let Some(b) = flags.get("batch-cap") {
        cfg.decode.batch_cap = b.parse().context("--batch-cap")?;
    }
    if let Some(c) = flags.get("prefill-chunk") {
        cfg.decode.prefill_chunk = c.parse().context("--prefill-chunk")?;
    }
    if flags.contains_key("decode")
        && flags.get("model").is_none()
        && flags.get("config").is_none()
        && !cfg.dnn.dataset.starts_with("seq")
    {
        // --decode without an explicit model: default to the zoo decoder
        cfg = cfg.with_model("gpt2_small", siam::dnn::default_dataset("gpt2_small"));
    }
    if flags.contains_key("quick") {
        cfg.serve.requests = cfg.serve.requests.min(200);
        if flags.contains_key("decode") {
            // token-level runs cost a pipeline pass per token: clamp the
            // stream and the generation length too
            cfg.serve.requests = cfg.serve.requests.min(32);
            cfg.decode.max_new_tokens = cfg.decode.max_new_tokens.min(8);
        }
    }
    cfg.validate()?;
    if flags.contains_key("decode") {
        return cmd_serve_decode(&cfg, flags);
    }

    // workload mix: "model", "model:dataset" or "file:path" entries;
    // empty = the [dnn] model
    let workloads: Vec<(String, String)> = if cfg.serve.workloads.is_empty() {
        vec![(cfg.dnn.model.clone(), cfg.dnn.dataset.clone())]
    } else {
        cfg.serve
            .workloads
            .iter()
            .map(|w| {
                let (m, d) = siam::dnn::split_workload(w, &cfg.dnn.dataset);
                (m.to_string(), d.to_string())
            })
            .collect()
    };

    let mut t = Table::new(&[
        "workload",
        "mode",
        "offered",
        "delivered inf/s",
        "ceiling inf/s",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "shed %",
        "util %",
    ]);
    let mut reports = Vec::new();
    // --trace captures the first workload's run (one trace file, one
    // pid-1 "serve" process track); further workloads run untraced
    let mut trace: Option<TraceBuffer> = None;
    for (i, (model, dataset)) in workloads.iter().enumerate() {
        let wcfg = cfg.clone().with_model(model, dataset);
        let rep = if i == 0 && flags.contains_key("trace") {
            let (r, buf) = siam::serve::serve_traced(&wcfg)?;
            trace = Some(buf);
            r
        } else {
            siam::serve::serve(&wcfg)?
        };
        t.row(&[
            format!("{model}/{dataset}"),
            rep.mode.clone(),
            match rep.mode.as_str() {
                "open" => format!("{:.0} qps", rep.offered_qps),
                _ => format!("conc {}", rep.concurrency),
            },
            format!("{:.1}", rep.throughput_qps),
            format!("{:.1}", rep.bottleneck_qps),
            format!("{:.3}", rep.p50_ms),
            format!("{:.3}", rep.p95_ms),
            format!("{:.3}", rep.p99_ms),
            format!("{:.1}", 100.0 * rep.drop_rate()),
            format!("{:.1}", 100.0 * rep.mean_utilization),
        ]);
        println!("{}\n", rep.summary());
        reports.push(rep);
    }
    t.print();
    if let (Some(path), Some(buf)) = (flags.get("trace"), &trace) {
        std::fs::write(path, buf.render())?;
        obs::log::info(&format!("wrote {path} ({} trace events)", buf.len()));
    }
    if let Some(path) = flags.get("json") {
        let mut out = Json::obj();
        out.set("schema", "siam-serve/v2")
            .set("reports", Json::Arr(reports.iter().map(|r| r.to_json()).collect()));
        std::fs::write(path, out.to_string_pretty())?;
        obs::log::info(&format!("wrote {path}"));
    }
    Ok(())
}

/// `siam serve --decode`: token-level autoregressive serving — one
/// decoder occupies the whole system, so there is no workload mix.
fn cmd_serve_decode(cfg: &SiamConfig, flags: &HashMap<String, String>) -> Result<()> {
    let (rep, trace) = if flags.contains_key("trace") {
        let (r, buf) = siam::serve::serve_decode_traced(cfg)?;
        (r, Some(buf))
    } else {
        (siam::serve::serve_decode(cfg)?, None)
    };
    println!("{}\n", rep.summary());
    let d = rep.decode.as_ref().expect("decode runs attach their block");
    let mut t = Table::new(&[
        "model",
        "mode",
        "offered",
        "tok/s",
        "TTFT p50 ms",
        "TPOT p50 ms",
        "batch peak",
        "KV peak kB",
        "shed %",
    ]);
    t.row(&[
        format!("{}/{}", rep.model, rep.dataset),
        rep.mode.clone(),
        match rep.mode.as_str() {
            "open" => format!("{:.0} qps", rep.offered_qps),
            _ => format!("conc {}", rep.concurrency),
        },
        format!("{:.1}", d.tokens_per_second),
        format!("{:.3}", d.ttft_p50_ms),
        format!("{:.4}", d.tpot_p50_ms),
        d.occupancy_peak.to_string(),
        format!("{:.1}", d.kv_peak_bytes as f64 / 1024.0),
        format!("{:.1}", 100.0 * rep.drop_rate()),
    ]);
    t.print();
    if let (Some(path), Some(buf)) = (flags.get("trace"), &trace) {
        std::fs::write(path, buf.render())?;
        obs::log::info(&format!("wrote {path} ({} trace events)", buf.len()));
    }
    if let Some(path) = flags.get("json") {
        let mut out = Json::obj();
        out.set("schema", "siam-serve/v2")
            .set("reports", Json::Arr(vec![rep.to_json()]));
        std::fs::write(path, out.to_string_pretty())?;
        obs::log::info(&format!("wrote {path}"));
    }
    Ok(())
}

fn cmd_functional(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let adc: u8 = flags.get("adc").map(String::as_str).unwrap_or("8").parse()?;
    let seed: u64 = flags.get("seed").map(String::as_str).unwrap_or("42").parse()?;
    let rt = siam::runtime::Runtime::open(dir)?;
    println!("PJRT platform: {}", rt.platform());
    let run = siam::runtime::functional::run_cnn(&rt, adc, seed)?;
    println!(
        "functional CNN (batch {}, ADC {} bits) in {:.3}s:",
        run.batch, run.adc_bits, run.exec_seconds
    );
    for b in 0..run.batch {
        let row = &run.logits[b * run.classes..(b + 1) * run.classes];
        let strs: Vec<String> = row.iter().map(|v| format!("{v:+.3}")).collect();
        println!("  image {b}: [{}] -> class {}", strs.join(", "), run.argmax()[b]);
    }
    Ok(())
}

/// One `models` table row: aggregate stats plus the crossbars the model
/// maps to at the paper-default geometry (128×128, 8-bit, custom
/// structure).
fn model_row(t: &mut Table, source: &str, name: &str, ds: &str, dnn: &siam::dnn::Dnn) {
    let s = dnn.stats();
    let xbars = siam::mapping::map_dnn(dnn, &SiamConfig::paper_default())
        .map(|m| m.total_xbars().to_string())
        .unwrap_or_else(|_| "-".into());
    t.row(&[
        name.to_string(),
        source.to_string(),
        ds.to_string(),
        format!("{:.2}", s.params as f64 / 1e6),
        format!("{:.2}", s.macs as f64 / 1e9),
        s.total_layers.to_string(),
        xbars,
    ]);
}

fn cmd_models(flags: &HashMap<String, String>) -> Result<()> {
    let mut t = Table::new(&[
        "model",
        "source",
        "dataset",
        "params (M)",
        "MACs (G)",
        "layers",
        "xbars@default",
    ]);
    for name in siam::dnn::zoo_names() {
        let ds = siam::dnn::default_dataset(name);
        let dnn = siam::dnn::build_model(name, ds)?;
        model_row(&mut t, "builtin", name, ds, &dnn);
    }
    // file models: every .toml under --files DIR (default configs/models).
    // A missing default directory is fine; an explicitly requested one
    // must exist. A broken file becomes an error row, not an abort —
    // the builtin listing stays usable.
    let explicit = flags.get("files").map(String::as_str);
    let dir = explicit.unwrap_or("configs/models");
    match std::fs::read_dir(dir) {
        Err(e) if explicit.is_some() => bail!("--files {dir}: {e}"),
        Err(_) => {}
        Ok(entries) => {
            let mut paths: Vec<_> = entries
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.extension().is_some_and(|x| x == "toml"))
                .collect();
            paths.sort();
            for path in paths {
                match siam::dnn::load_model_file(&path) {
                    Ok(dnn) => {
                        let (name, ds) = (dnn.name.clone(), dnn.dataset.clone());
                        model_row(&mut t, &format!("file:{}", path.display()), &name, &ds, &dnn);
                    }
                    Err(e) => t.row(&[
                        path.display().to_string(),
                        "file".into(),
                        format!("ERROR: {e}"),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]),
                }
            }
        }
    }
    t.print();
    Ok(())
}

const USAGE: &str = "usage: siam <simulate|sweep|serve|functional|models|config> [flags]
  simulate   --model resnet110 --dataset cifar10 [--tiles 16] [--chiplets 36]
             [--monolithic] [--placement rowmajor|dataflow]
             [--spares 2] [--kill-chiplet 3,7] [--fault-seed 42]
             [--cache-file epochs.cache] [--trace trace.json] [--profile]
             [--config file.toml] [--json out.json]
  sweep      --model resnet110 --dataset cifar10 [--tiles 4,9,16] [--counts 36,64]
             [--placement rowmajor|dataflow]
             [--fom edap|edp|energy|latency|area|ipj|yield|variation]
             [--cache-file epochs.cache] [--search exhaustive|pareto|halving]
             [--halving-keep 0.5] [--profile] [--json out.json]
  serve      [--mode open|closed] [--rate 2000] [--concurrency 4]
             [--requests 1024] [--queue 4] [--seed 42] [--quick]
             [--fail-at 64 --fail-chiplet 3 --remap-latency 100 --spares 1]
             [--decode] [--max-new-tokens 32] [--kv-bits 8]
             [--batch-cap 8] [--prefill-chunk 0]
             [--cache-file epochs.cache] [--trace trace.json]
             [--config file.toml] [--json out.json]
  functional [--artifacts artifacts] [--adc 4|8] [--seed 42]
  models     [--files DIR] list builtin + file models (params/MACs/crossbars)
  config     print the paper-default configuration TOML

  every command accepts --log-level quiet|normal|verbose (progress
  narration on stderr; results stay on stdout)
  --trace writes a deterministic Chrome trace (open in Perfetto or
  chrome://tracing); --profile prints host wall-clock per stage and adds
  a profile fragment to --json output (docs/OBSERVABILITY.md)
  --model also accepts a network-description file: --model file:net.toml
  --spares reserves idle spare chiplets; --kill-chiplet injects faults
  (docs/RELIABILITY.md); serve --fail-at kills --fail-chiplet mid-run and
  hot-swaps the remapped pipeline after --remap-latency microseconds
  (see docs/MODELS.md for the model-authoring format)
  serve --decode runs token-level autoregressive serving on a decoder
  (prefill + per-token decode steps, KV-cache residency with DRAM spill,
  continuous batching up to --batch-cap); TTFT/TPOT/tokens-per-second
  land in the report's decode block (docs/MODELS.md)
  a [variation] config block adds analog device variation (programming
  noise, drift, stuck-at cells, ADC offset) to every command; sweep
  --fom variation prunes points below the accuracy floor
  (configs/variation_demo.toml, docs/RELIABILITY.md)
  --cache-file persists simulated NoC/NoP epochs across runs: warm runs
  replay instead of re-simulating, bit-identically; sweep --search
  pareto|halving prunes the grid with a certified cheap-bound pass and
  still returns the exhaustive optimum (docs/CACHING.md)";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args)?;
    if let Some(l) = flags.get("log-level") {
        match LogLevel::parse(l) {
            Some(level) => obs::log::set_level(level),
            None => bail!("--log-level must be quiet|normal|verbose, got '{l}'"),
        }
    }
    if flags.contains_key("help") || pos.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    match pos[0].as_str() {
        "simulate" => cmd_simulate(&flags),
        "sweep" => cmd_sweep(&flags),
        "serve" => cmd_serve(&flags),
        "functional" => cmd_functional(&flags),
        "models" => cmd_models(&flags),
        "config" => {
            print!("{}", SiamConfig::paper_default().to_toml_string()?);
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}
