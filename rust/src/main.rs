//! SIAM command-line launcher.
//!
//! ```text
//! siam simulate  [--config F] [--model M --dataset D] [--tiles N]
//!                [--chiplets N] [--monolithic] [--json PATH]
//! siam sweep     [--config F] [--model M --dataset D]
//!                [--tiles 4,9,16,25,36] [--counts 16,36,64,100]
//! siam functional [--artifacts DIR] [--adc 8] [--seed 42]
//! siam models
//! siam config    (print the paper-default TOML)
//! ```
//!
//! Argument parsing is in-tree (the offline build vendors no clap).

use anyhow::{bail, Context, Result};
use siam::config::{ChipMode, SiamConfig};
use siam::coordinator::{self, simulate};
use siam::util::table::{eng, Table};
use std::collections::HashMap;

fn parse_flags(args: &[String]) -> Result<(Vec<String>, HashMap<String, String>)> {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            // boolean flags take no value
            if matches!(name, "monolithic" | "help") {
                flags.insert(name.to_string(), "true".into());
                i += 1;
            } else {
                let v = args
                    .get(i + 1)
                    .with_context(|| format!("--{name} needs a value"))?;
                flags.insert(name.to_string(), v.clone());
                i += 2;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    Ok((pos, flags))
}

fn build_config(flags: &HashMap<String, String>) -> Result<SiamConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => SiamConfig::from_toml_file(path)?,
        None => SiamConfig::paper_default(),
    };
    if let Some(m) = flags.get("model") {
        cfg.dnn.model = m.clone();
    }
    if let Some(d) = flags.get("dataset") {
        cfg.dnn.dataset = d.clone();
    }
    if let Some(t) = flags.get("tiles") {
        cfg.chiplet.tiles_per_chiplet = t.parse().context("--tiles")?;
    }
    if let Some(c) = flags.get("chiplets") {
        cfg = cfg.with_total_chiplets(c.parse().context("--chiplets")?);
    }
    if flags.contains_key("monolithic") {
        cfg.system.chip_mode = ChipMode::Monolithic;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn parse_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|p| p.trim().parse::<usize>().context("bad list element"))
        .collect()
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = build_config(flags)?;
    let rep = simulate(&cfg)?;
    println!("{}", rep.summary());
    if let Some(path) = flags.get("json") {
        std::fs::write(path, rep.to_json().to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = build_config(flags)?;
    let tiles = parse_list(flags.get("tiles").map(String::as_str).unwrap_or("4,9,16,25,36"))?;
    let counts: Vec<Option<usize>> = match flags.get("counts") {
        Some(c) => parse_list(c)?.into_iter().map(Some).chain([None]).collect(),
        None => vec![None],
    };
    let pts = coordinator::sweep(&cfg, &tiles, &counts)?;
    let mut t = Table::new(&[
        "tiles/chiplet",
        "chiplets",
        "area mm2",
        "energy uJ",
        "latency ms",
        "EDAP",
    ]);
    for p in &pts {
        t.row(&[
            p.tiles_per_chiplet.to_string(),
            p.total_chiplets
                .map(|c| c.to_string())
                .unwrap_or_else(|| format!("custom({})", p.report.num_chiplets)),
            eng(p.report.total.area_mm2()),
            eng(p.report.total.energy_uj()),
            eng(p.report.total.latency_ms()),
            format!("{:.3e}", p.report.total.edap()),
        ]);
    }
    t.print();
    if let Some(best) = coordinator::dse::best_by_edap(&pts) {
        println!(
            "\nEDAP-optimal: {} tiles/chiplet, {} chiplets",
            best.tiles_per_chiplet, best.report.num_chiplets
        );
    }
    Ok(())
}

fn cmd_functional(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags.get("artifacts").map(String::as_str).unwrap_or("artifacts");
    let adc: u8 = flags.get("adc").map(String::as_str).unwrap_or("8").parse()?;
    let seed: u64 = flags.get("seed").map(String::as_str).unwrap_or("42").parse()?;
    let rt = siam::runtime::Runtime::open(dir)?;
    println!("PJRT platform: {}", rt.platform());
    let run = siam::runtime::functional::run_cnn(&rt, adc, seed)?;
    println!(
        "functional CNN (batch {}, ADC {} bits) in {:.3}s:",
        run.batch, run.adc_bits, run.exec_seconds
    );
    for b in 0..run.batch {
        let row = &run.logits[b * run.classes..(b + 1) * run.classes];
        let strs: Vec<String> = row.iter().map(|v| format!("{v:+.3}")).collect();
        println!("  image {b}: [{}] -> class {}", strs.join(", "), run.argmax()[b]);
    }
    Ok(())
}

fn cmd_models() -> Result<()> {
    let mut t = Table::new(&["model", "dataset", "params (M)", "MACs (G)", "layers"]);
    for name in siam::dnn::zoo_names() {
        let ds = match *name {
            "resnet50" | "vgg16" => "imagenet",
            "vgg19" => "cifar100",
            "drivenet" => "drivenet",
            _ => "cifar10",
        };
        let dnn = siam::dnn::build_model(name, ds)?;
        let s = dnn.stats();
        t.row(&[
            name.to_string(),
            ds.to_string(),
            format!("{:.2}", s.params as f64 / 1e6),
            format!("{:.2}", s.macs as f64 / 1e9),
            s.total_layers.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

const USAGE: &str = "usage: siam <simulate|sweep|functional|models|config> [flags]
  simulate   --model resnet110 --dataset cifar10 [--tiles 16] [--chiplets 36]
             [--monolithic] [--config file.toml] [--json out.json]
  sweep      --model resnet110 --dataset cifar10 [--tiles 4,9,16] [--counts 36,64]
  functional [--artifacts artifacts] [--adc 4|8] [--seed 42]
  models     list the model zoo
  config     print the paper-default configuration TOML";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args)?;
    if flags.contains_key("help") || pos.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    match pos[0].as_str() {
        "simulate" => cmd_simulate(&flags),
        "sweep" => cmd_sweep(&flags),
        "functional" => cmd_functional(&flags),
        "models" => cmd_models(),
        "config" => {
            print!("{}", SiamConfig::paper_default().to_toml_string()?);
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}
