//! Fixed-width table printer for bench harness output — every bench
//! regenerates one paper table/figure as rows on stdout.

/// Simple left-aligned table with a header rule.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render to an aligned string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with engineering-style precision (3 significant-ish
/// digits) for table cells.
pub fn eng(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if a >= 1000.0 {
        format!("{v:.0}")
    } else if a >= 10.0 {
        format!("{v:.1}")
    } else if a >= 0.01 {
        format!("{v:.3}")
    } else {
        format!("{v:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["net", "value"]);
        t.row(&["resnet110".into(), "1.7".into()]);
        t.row(&["x".into(), "12345".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("net      "));
        assert!(lines[2].starts_with("resnet110"));
    }

    #[test]
    #[should_panic(expected = "column mismatch")]
    fn wrong_arity_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn eng_formats() {
        assert_eq!(eng(0.0), "0");
        assert_eq!(eng(12345.6), "12346");
        assert_eq!(eng(12.34), "12.3");
        assert_eq!(eng(0.5), "0.500");
        assert!(eng(1e-5).contains('e'));
    }
}
