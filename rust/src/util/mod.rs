//! Small in-tree utilities: deterministic PRNG (the offline build vendors
//! no `rand`), a minimal JSON writer for machine-readable reports, and a
//! fixed-width table printer for the bench harnesses.

pub mod json;
pub mod table;

/// xorshift64* PRNG — deterministic, seedable, good enough for synthetic
/// workloads and property tests (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    /// Seeded generator (seed 0 is remapped to 1).
    pub fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // rejection-free modulo is fine for our non-crypto uses
        self.next_u64() % n
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Run a closure over `n` random cases — a tiny property-test harness.
/// On failure, reports the failing case index and seed for reproduction.
pub fn check_property<F: FnMut(&mut Rng)>(name: &str, cases: usize, seed: u64, mut f: F) {
    for i in 0..cases {
        let case_seed = seed.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {i} (seed {case_seed:#x}): {e:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range(5, 8);
            assert!((5..=8).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn property_harness_reports_failure() {
        check_property("always_fails", 3, 1, |_| panic!("boom"));
    }
}
