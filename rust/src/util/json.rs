//! Minimal JSON value + writer (reports only need objects, arrays,
//! strings and numbers) and a tolerant reader for the artifact manifest.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert a key (panics on non-objects); chainable.
    pub fn set(&mut self, key: &str, v: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        } else {
            panic!("set on non-object");
        }
        self
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Render with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                let _ = write!(out, "\"{}\"", escape(s));
            }
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    v.write(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  \"{}\": ", escape(k));
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            '\n' => vec!['\\', 'n'],
            c => vec![c],
        })
        .collect()
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Recursive-descent JSON parser (for `artifacts/manifest.json`).
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    out.push(match e {
                        b'n' => '\n',
                        b't' => '\t',
                        b'u' => {
                            // \uXXXX — manifest never needs it, decode BMP
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad unicode escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad unicode escape")?;
                            self.i += 4;
                            char::from_u32(cp).unwrap_or('?')
                        }
                        other => other as char,
                    });
                }
                _ => out.push(c as char),
            }
        }
        Err("unterminated string".into())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut o = Json::obj();
        o.set("name", "siam").set("x", 1.5_f64).set("n", 42_usize);
        o.set("arr", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
        let text = o.to_string_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn parses_manifest_like() {
        let text = r#"[
          {"name": "xbar_gemm_64x128x64_adc4",
           "file": "xbar_gemm_64x128x64_adc4.hlo.txt",
           "params": [[64, 128], [128, 64]],
           "output": [64, 64],
           "meta": {"kind": "xbar_gemm", "adc_bits": 4}}
        ]"#;
        let v = parse(text).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(
            arr[0].get("meta").unwrap().get("adc_bits").unwrap().as_f64(),
            Some(4.0)
        );
        let params = arr[0].get("params").unwrap().as_arr().unwrap();
        assert_eq!(params[0].as_arr().unwrap()[1].as_f64(), Some(128.0));
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        let text = j.to_string_pretty();
        assert_eq!(parse(&text).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{bad}").is_err());
        assert!(parse("[1, 2,,]").is_err());
        assert!(parse("").is_err());
    }
}
