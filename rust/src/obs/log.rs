//! Leveled progress logging behind the `--log-level` CLI flag.
//!
//! One process-wide level (default [`LogLevel::Normal`]) gates the
//! progress prints that used to be scattered `eprintln!`/`println!`
//! calls: [`info`] for normal progress notes, [`verbose`] for chatty
//! per-step detail. Primary program *output* (tables, reports, JSON)
//! does not route through here — only narration about progress does, so
//! `--log-level quiet` leaves the results readable and scripts
//! parseable.

use std::sync::atomic::{AtomicU8, Ordering};

/// How much progress narration to print.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    /// Results only; no progress notes.
    Quiet,
    /// Default: one-line progress notes ([`info`]).
    Normal,
    /// Everything, including per-step detail ([`verbose`]).
    Verbose,
}

static LEVEL: AtomicU8 = AtomicU8::new(LogLevel::Normal as u8);

impl LogLevel {
    /// Parse a `--log-level` value (`quiet` / `normal` / `verbose`).
    pub fn parse(s: &str) -> Option<LogLevel> {
        match s {
            "quiet" => Some(LogLevel::Quiet),
            "normal" => Some(LogLevel::Normal),
            "verbose" => Some(LogLevel::Verbose),
            _ => None,
        }
    }
}

/// Set the process-wide log level.
pub fn set_level(level: LogLevel) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current process-wide log level.
pub fn level() -> LogLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => LogLevel::Quiet,
        2 => LogLevel::Verbose,
        _ => LogLevel::Normal,
    }
}

/// Print a progress note at `Normal` and above (to stderr, keeping
/// stdout clean for results).
pub fn info(msg: &str) {
    if level() >= LogLevel::Normal {
        eprintln!("{msg}");
    }
}

/// Print per-step detail at `Verbose` only (to stderr).
pub fn verbose(msg: &str) {
    if level() >= LogLevel::Verbose {
        eprintln!("{msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_and_orders() {
        assert_eq!(LogLevel::parse("quiet"), Some(LogLevel::Quiet));
        assert_eq!(LogLevel::parse("normal"), Some(LogLevel::Normal));
        assert_eq!(LogLevel::parse("verbose"), Some(LogLevel::Verbose));
        assert_eq!(LogLevel::parse("debug"), None);
        assert!(LogLevel::Quiet < LogLevel::Normal && LogLevel::Normal < LogLevel::Verbose);
    }

    #[test]
    fn set_level_is_observable() {
        let before = level();
        set_level(LogLevel::Verbose);
        assert_eq!(level(), LogLevel::Verbose);
        set_level(before); // restore for other tests in the process
    }
}
