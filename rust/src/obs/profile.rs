//! Self-profiling spans: host wall-clock timings aggregated per label.
//!
//! A [`Profiler`] is shared by reference across the pipeline stages and
//! the sweep workers (it is `Sync`; the sweep builder holds it behind an
//! `Arc`). Every span is folded into per-label statistics under a
//! poison-tolerant mutex — profiling observes wall-clock only and never
//! feeds back into simulated state, so profiled runs stay bit-identical
//! to unprofiled ones (regression-pinned by the observability tests).

use crate::util::json::Json;
use crate::util::table::Table;
use std::collections::BTreeMap;
use std::sync::{Mutex, PoisonError};
use std::time::Instant;

/// Aggregated statistics of one span label.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpanStat {
    /// Number of spans recorded under the label.
    pub calls: u64,
    /// Total wall-clock across all calls, seconds.
    pub total_s: f64,
    /// Longest single call, seconds.
    pub max_s: f64,
}

/// Label-keyed span aggregator for host wall-clock attribution.
#[derive(Debug, Default)]
pub struct Profiler {
    spans: Mutex<BTreeMap<String, SpanStat>>,
}

impl Profiler {
    /// An empty profiler.
    pub fn new() -> Profiler {
        Profiler::default()
    }

    /// Fold one span of `secs` seconds into `label`'s statistics.
    pub fn record(&self, label: &str, secs: f64) {
        let mut spans = self.spans.lock().unwrap_or_else(PoisonError::into_inner);
        let s = spans.entry(label.to_string()).or_default();
        s.calls += 1;
        s.total_s += secs;
        s.max_s = s.max_s.max(secs);
    }

    /// Run `f`, recording its wall-clock under `label`.
    pub fn time<R>(&self, label: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.record(label, t0.elapsed().as_secs_f64());
        r
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.spans.lock().unwrap_or_else(PoisonError::into_inner).is_empty()
    }

    /// All labels and their statistics, sorted by total time
    /// (descending; label breaks ties).
    pub fn snapshot(&self) -> Vec<(String, SpanStat)> {
        let spans = self.spans.lock().unwrap_or_else(PoisonError::into_inner);
        let mut v: Vec<(String, SpanStat)> =
            spans.iter().map(|(k, s)| (k.clone(), *s)).collect();
        v.sort_by(|a, b| b.1.total_s.total_cmp(&a.1.total_s).then_with(|| a.0.cmp(&b.0)));
        v
    }

    /// Render the aggregated spans as an aligned table.
    pub fn render_table(&self) -> String {
        let snap = self.snapshot();
        let grand: f64 = snap.iter().map(|(_, s)| s.total_s).sum();
        let mut t = Table::new(&["span", "calls", "total ms", "mean ms", "max ms", "share %"]);
        for (label, s) in &snap {
            t.row(&[
                label.clone(),
                s.calls.to_string(),
                format!("{:.3}", s.total_s * 1e3),
                format!("{:.3}", s.total_s * 1e3 / s.calls.max(1) as f64),
                format!("{:.3}", s.max_s * 1e3),
                format!("{:.1}", 100.0 * s.total_s / grand.max(1e-12)),
            ]);
        }
        t.render()
    }

    /// The `profile` JSON fragment: one object per label with
    /// calls/total/mean/max in milliseconds.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        for (label, s) in self.snapshot() {
            let mut e = Json::obj();
            e.set("calls", s.calls)
                .set("total_ms", s.total_s * 1e3)
                .set("mean_ms", s.total_s * 1e3 / s.calls.max(1) as f64)
                .set("max_ms", s.max_s * 1e3);
            o.set(&label, e);
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_aggregate_per_label() {
        let p = Profiler::new();
        p.record("a", 0.010);
        p.record("a", 0.030);
        p.record("b", 0.005);
        let snap = p.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "a", "sorted by total time");
        assert_eq!(snap[0].1.calls, 2);
        assert!((snap[0].1.total_s - 0.040).abs() < 1e-12);
        assert!((snap[0].1.max_s - 0.030).abs() < 1e-12);
        let table = p.render_table();
        assert!(table.contains("span") && table.contains('a') && table.contains('b'));
        let j = p.to_json();
        assert!(j.get("a").and_then(|a| a.get("calls")).is_some());
    }

    #[test]
    fn time_returns_the_closure_value() {
        let p = Profiler::new();
        let v = p.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(p.snapshot()[0].1.calls, 1);
        assert!(!p.is_empty());
    }
}
