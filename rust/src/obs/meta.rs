//! Run metadata: the self-describing `meta` block every report and
//! bench JSON carries.
//!
//! A [`RunMeta`] pins everything needed to reproduce and attribute one
//! run: the meta-schema version, an FNV-1a fingerprint of the complete
//! serialized configuration, the seeds in play, the resolved model
//! source, host wall-clock, and — when an epoch cache / the flow engine
//! were involved — the cache hit/miss/per-shard statistics and the
//! engine-tier counters. The fingerprint covers `to_toml_string()`
//! output, so any config drift (including defaults) changes it.

use crate::config::SiamConfig;
use crate::noc::{EpochCache, TierCounts};
use crate::util::json::Json;

/// Version tag of the `meta` block layout itself.
pub const META_SCHEMA: &str = "siam-meta/v1";

/// Point-in-time statistics of one [`EpochCache`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    /// Total lookup hits across all shards.
    pub hits: u64,
    /// Total lookup misses (= epoch simulations) across all shards.
    pub misses: u64,
    /// Entries resident in the cache.
    pub entries: usize,
    /// Entries hydrated from a persistent store rather than computed —
    /// warm runs report their reuse here instead of masquerading as
    /// fresh simulations.
    pub hydrated: u64,
    /// Per-shard `(hits, misses)` in shard order.
    pub shards: Vec<(u64, u64)>,
}

impl CacheSnapshot {
    /// Capture the current counters of `cache`.
    pub fn capture(cache: &EpochCache) -> CacheSnapshot {
        CacheSnapshot {
            hits: cache.hits(),
            misses: cache.misses(),
            entries: cache.len(),
            hydrated: cache.hydrated(),
            shards: cache.shard_stats(),
        }
    }

    /// Hit fraction in [0, 1] (0 when no lookups happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// The `epoch_cache` JSON fragment.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("hits", self.hits)
            .set("misses", self.misses)
            .set("hit_rate", self.hit_rate())
            .set("entries", self.entries)
            .set("hydrated", self.hydrated);
        let shards: Vec<Json> = self
            .shards
            .iter()
            .map(|&(h, m)| {
                let mut s = Json::obj();
                s.set("hits", h).set("misses", m);
                s
            })
            .collect();
        o.set("shards", shards);
        o
    }
}

/// The self-describing metadata of one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunMeta {
    /// FNV-1a 64-bit fingerprint of the serialized config, `%016x`.
    pub config_fingerprint: String,
    /// Resolved workload provenance (`builtin` or `file:path#fp`).
    pub model_source: String,
    /// Named seeds feeding the run's random streams.
    pub seeds: Vec<(String, u64)>,
    /// Host wall-clock of the run, seconds.
    pub wall_seconds: f64,
    /// Epoch-cache statistics, when a cache served the run.
    pub epoch_cache: Option<CacheSnapshot>,
    /// Flow-engine tier counters, when mesh epochs were simulated.
    pub engine_tiers: Option<TierCounts>,
}

impl RunMeta {
    /// Start a meta block for `cfg`: fingerprint and seeds filled in,
    /// everything else at its default for the caller to set.
    pub fn for_config(cfg: &SiamConfig) -> RunMeta {
        RunMeta {
            config_fingerprint: config_fingerprint(cfg),
            seeds: vec![
                ("serve".into(), cfg.serve.seed),
                ("fault".into(), cfg.fault.seed),
                ("variation".into(), cfg.variation.seed),
            ],
            ..RunMeta::default()
        }
    }

    /// The `meta` JSON fragment.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("schema", META_SCHEMA)
            .set("config_fingerprint", self.config_fingerprint.as_str())
            .set("model_source", self.model_source.as_str())
            .set("wall_seconds", self.wall_seconds);
        let mut seeds = Json::obj();
        for (name, seed) in &self.seeds {
            seeds.set(name, *seed);
        }
        o.set("seeds", seeds);
        if let Some(c) = &self.epoch_cache {
            o.set("epoch_cache", c.to_json());
        }
        if let Some(t) = &self.engine_tiers {
            o.set("engine_tiers", t.to_json());
        }
        o
    }
}

/// FNV-1a 64-bit hash of a byte string.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_seeded(0xcbf2_9ce4_8422_2325, bytes)
}

/// FNV-1a with a caller-chosen initial state — the second lane of the
/// 128-bit point fingerprint decorrelates from the first by seeding
/// with a perturbed copy of it.
fn fnv1a_seeded(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// 128-bit fingerprint of one complete configuration, as two FNV-1a
/// lanes over the serialized TOML: the standard hash, and a second pass
/// seeded from the first (golden-ratio perturbed). Sweep runs record
/// these in the persistent epoch cache so re-runs can tell edited
/// design points from already-explored ones.
pub fn point_fingerprint(cfg: &SiamConfig) -> (u64, u64) {
    let text = cfg.to_toml_string().unwrap_or_default();
    let lo = fnv1a(text.as_bytes());
    let hi = fnv1a_seeded(lo ^ 0x9e37_79b9_7f4a_7c15, text.as_bytes());
    (lo, hi)
}

/// Fingerprint of the complete serialized configuration, `%016x`
/// (empty-string hash if the config cannot serialize — it always can
/// for validated configs).
pub fn config_fingerprint(cfg: &SiamConfig) -> String {
    let text = cfg.to_toml_string().unwrap_or_default();
    format!("{:016x}", fnv1a(text.as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_stable_and_config_sensitive() {
        // pinned FNV-1a test vectors
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        let base = SiamConfig::paper_default();
        let a = config_fingerprint(&base);
        assert_eq!(a, config_fingerprint(&base), "fingerprint must be deterministic");
        let b = config_fingerprint(&base.clone().with_tiles_per_chiplet(25));
        assert_ne!(a, b, "a config change must change the fingerprint");
        assert_eq!(a.len(), 16);
    }

    #[test]
    fn point_fingerprints_are_stable_and_lane_independent() {
        let base = SiamConfig::paper_default();
        let (lo, hi) = point_fingerprint(&base);
        assert_eq!((lo, hi), point_fingerprint(&base), "must be deterministic");
        assert_ne!(lo, hi, "the two lanes must decorrelate");
        // the first lane is the config fingerprint everyone else reports
        assert_eq!(format!("{lo:016x}"), config_fingerprint(&base));
        let edited = point_fingerprint(&base.clone().with_tiles_per_chiplet(25));
        assert_ne!((lo, hi), edited, "a config edit must change the fingerprint");
    }

    #[test]
    fn meta_json_carries_the_stable_keys() {
        let mut m = RunMeta::for_config(&SiamConfig::paper_default());
        m.model_source = "builtin".into();
        m.wall_seconds = 1.25;
        m.epoch_cache = Some(CacheSnapshot {
            hits: 3,
            misses: 1,
            entries: 1,
            hydrated: 2,
            shards: vec![(3, 1)],
        });
        m.engine_tiers = Some(TierCounts::default());
        let j = m.to_json();
        let keys = [
            "schema",
            "config_fingerprint",
            "model_source",
            "seeds",
            "wall_seconds",
            "epoch_cache",
            "engine_tiers",
        ];
        for key in keys {
            assert!(j.get(key).is_some(), "meta missing {key}");
        }
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(META_SCHEMA));
        let cache = j.get("epoch_cache").unwrap();
        assert_eq!(cache.get("hit_rate").and_then(Json::as_f64), Some(0.75));
        assert_eq!(cache.get("hydrated").and_then(Json::as_f64), Some(2.0));
    }
}
