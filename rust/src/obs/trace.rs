//! Chrome trace-event buffer: deterministic, simulated-time event
//! streams rendered as Perfetto-loadable JSON.
//!
//! The format is the Trace Event Format's JSON-array flavour: the file
//! is an array of event objects, each carrying at least `name`, `ph`
//! (phase), `ts` (timestamp, microseconds), `pid` and `tid`. Three
//! phases are emitted: `"X"` complete events (spans with `dur`), `"i"`
//! instant events, and `"M"` metadata events naming the process/thread
//! tracks. Timestamps come from the *simulation* clock (nanoseconds,
//! converted to microseconds here), never from the host clock, so two
//! traced runs of the same `(config, seed)` render byte-identical
//! streams — asserted by the observability tests.

use crate::util::json::Json;

/// An append-only buffer of Chrome trace events.
///
/// Producers push events in deterministic order; [`TraceBuffer::render`]
/// serializes them as a JSON array (keys within each event are sorted by
/// the writer, so the bytes are a pure function of the pushed events).
#[derive(Debug, Clone, Default)]
pub struct TraceBuffer {
    events: Vec<Json>,
}

/// Build the common `{name, ph, ts, pid, tid}` skeleton every event
/// variant shares.
fn base(name: &str, ph: &str, ts_us: f64, pid: u32, tid: u32) -> Json {
    let mut e = Json::obj();
    e.set("name", name)
        .set("ph", ph)
        .set("ts", ts_us)
        .set("pid", pid as u64)
        .set("tid", tid as u64);
    e
}

impl TraceBuffer {
    /// An empty buffer.
    pub fn new() -> TraceBuffer {
        TraceBuffer::default()
    }

    /// Number of events recorded so far.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when no event has been recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// A complete (`"X"`) span: `[ts, ts + dur)` on track `(pid, tid)`,
    /// timestamps in simulated nanoseconds. Pass `Json::Null` for no
    /// args.
    pub fn complete(
        &mut self,
        name: &str,
        ts_ns: f64,
        dur_ns: f64,
        pid: u32,
        tid: u32,
        args: Json,
    ) {
        let mut e = base(name, "X", ts_ns / 1e3, pid, tid);
        e.set("dur", dur_ns / 1e3);
        if !matches!(args, Json::Null) {
            e.set("args", args);
        }
        self.events.push(e);
    }

    /// An instant (`"i"`) event at `ts_ns` on track `(pid, tid)`. Pass
    /// `Json::Null` for no args.
    pub fn instant(&mut self, name: &str, ts_ns: f64, pid: u32, tid: u32, args: Json) {
        let mut e = base(name, "i", ts_ns / 1e3, pid, tid);
        e.set("s", "t"); // thread-scoped instant
        if !matches!(args, Json::Null) {
            e.set("args", args);
        }
        self.events.push(e);
    }

    /// A `process_name` metadata event labelling `pid` in the viewer.
    pub fn process_name(&mut self, pid: u32, name: &str) {
        let mut e = base("process_name", "M", 0.0, pid, 0);
        let mut args = Json::obj();
        args.set("name", name);
        e.set("args", args);
        self.events.push(e);
    }

    /// A `thread_name` metadata event labelling `(pid, tid)` in the
    /// viewer.
    pub fn thread_name(&mut self, pid: u32, tid: u32, name: &str) {
        let mut e = base("thread_name", "M", 0.0, pid, tid);
        let mut args = Json::obj();
        args.set("name", name);
        e.set("args", args);
        self.events.push(e);
    }

    /// The whole buffer as a JSON array value.
    pub fn to_json(&self) -> Json {
        Json::from(self.events.clone())
    }

    /// Render the Chrome trace JSON (an array of event objects).
    pub fn render(&self) -> String {
        self.to_json().to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_carry_the_required_keys() {
        let mut t = TraceBuffer::new();
        t.process_name(1, "serve");
        t.thread_name(1, 2, "stage 2");
        t.complete("serve", 1500.0, 3000.0, 1, 2, Json::Null);
        let mut args = Json::obj();
        args.set("req", 7u64);
        t.instant("admit", 500.0, 1, 0, args);
        assert_eq!(t.len(), 4);
        let arr = t.to_json();
        let events = arr.as_arr().expect("trace is an array");
        for e in events {
            for key in ["name", "ph", "ts", "pid", "tid"] {
                assert!(e.get(key).is_some(), "event missing {key}");
            }
        }
        // ns -> us conversion
        assert_eq!(events[2].get("ts").and_then(Json::as_f64), Some(1.5));
        assert_eq!(events[2].get("dur").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn rendering_is_deterministic() {
        let build = || {
            let mut t = TraceBuffer::new();
            t.process_name(1, "p");
            t.complete("a", 0.0, 10.0, 1, 1, Json::Null);
            t.instant("b", 5.0, 1, 1, Json::Null);
            t.render()
        };
        assert_eq!(build(), build());
    }
}
