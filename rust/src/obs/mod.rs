//! Observability: deterministic event tracing, self-profiling spans and
//! run metadata — the instrumentation layer of the simulator.
//!
//! Three zero-dependency parts (see `docs/OBSERVABILITY.md` for the
//! user-facing guide):
//!
//! * [`trace`] — a Chrome trace-event buffer ([`trace::TraceBuffer`])
//!   that the serving event loop and the pipeline stages write
//!   structured events into, in *simulated* time. The rendered JSON
//!   loads directly into Perfetto / `chrome://tracing`. Tracing is
//!   observational only: the producers call it through sink traits with
//!   no-op defaults, so untraced runs stay bit-identical and
//!   allocation-free on the hot path (regression-pinned).
//! * [`profile`] — host wall-clock spans ([`profile::Profiler`])
//!   aggregated per label into a table / JSON fragment, attributing
//!   sweep and pipeline wall-clock to stages without perturbing any
//!   simulated result.
//! * [`meta`] — the self-describing run-metadata block
//!   ([`meta::RunMeta`]) every report and bench JSON carries: schema
//!   version, config fingerprint, seeds, model source, wall-clock,
//!   epoch-cache statistics and engine-tier counters.
//!
//! [`log`] is the tiny leveled logging helper behind the `--log-level`
//! CLI flag; progress prints route through it instead of ad-hoc
//! `eprintln!` calls.

pub mod log;
pub mod meta;
pub mod profile;
pub mod trace;

pub use log::LogLevel;
pub use meta::{CacheSnapshot, RunMeta};
pub use profile::Profiler;
pub use trace::TraceBuffer;
