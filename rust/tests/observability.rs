//! Observability regression gates: tracing, profiling and the meta
//! block must be pure observers. A traced or profiled run has to stay
//! bit-identical to the plain run, two traced runs of the same
//! `(config, seed)` must render byte-identical Chrome traces, and every
//! report JSON must carry a well-formed `meta` block.

use siam::config::SiamConfig;
use siam::coordinator::{self, SimReport, SweepContext};
use siam::obs::{LogLevel, Profiler, TraceBuffer};
use siam::serve;
use siam::util::check_property;
use siam::util::json::Json;

/// The deterministic fields two [`SimReport`]s of the same point must
/// share bit-for-bit (meta/wall-clock excluded — those carry host
/// timing by design).
fn assert_sim_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.model, b.model);
    assert_eq!(a.num_chiplets, b.num_chiplets);
    assert_eq!(a.total_tiles, b.total_tiles);
    assert_eq!(a.noc_cycles, b.noc_cycles);
    assert_eq!(a.nop_cycles, b.nop_cycles);
    assert_eq!(a.engine_tiers, b.engine_tiers, "tier counters must be deterministic");
    for (x, y) in [
        (a.total.energy_pj, b.total.energy_pj),
        (a.total.latency_ns, b.total.latency_ns),
        (a.total.area_um2, b.total.area_um2),
        (a.total.leakage_uw, b.total.leakage_uw),
        (a.circuit.energy_pj, b.circuit.energy_pj),
        (a.noc.energy_pj, b.noc.energy_pj),
        (a.nop.energy_pj, b.nop.energy_pj),
        (a.xbar_utilization, b.xbar_utilization),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
    }
}

/// The deterministic fields two serving reports of the same
/// `(config, seed)` must share bit-for-bit.
fn assert_serve_identical(a: &coordinator::ServeReport, b: &coordinator::ServeReport) {
    assert_eq!(a.mode, b.mode);
    assert_eq!(a.num_stages, b.num_stages);
    assert_eq!(a.bottleneck_stage, b.bottleneck_stage);
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.dropped, b.dropped);
    for (x, y) in [
        (a.throughput_qps, b.throughput_qps),
        (a.p50_ms, b.p50_ms),
        (a.p95_ms, b.p95_ms),
        (a.p99_ms, b.p99_ms),
        (a.mean_ms, b.mean_ms),
        (a.mean_utilization, b.mean_utilization),
        (a.energy_per_inference_pj, b.energy_per_inference_pj),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
    }
}

/// Every event of a rendered trace carries the Trace Event Format's
/// five required keys.
fn assert_trace_wellformed(trace: &TraceBuffer) {
    let arr = trace.to_json();
    let events = arr.as_arr().expect("trace is a JSON array");
    assert!(!events.is_empty(), "trace must record events");
    for e in events {
        for key in ["name", "ph", "ts", "pid", "tid"] {
            assert!(e.get(key).is_some(), "event missing {key}: {}", e.to_string_pretty());
        }
    }
}

fn quick_serve_cfg() -> SiamConfig {
    SiamConfig::paper_default()
        .with_model("resnet20", "cifar10")
        .with_serve_requests(256)
}

#[test]
fn engine_sink_observation_is_bit_identical() {
    // property: over random synthetic pipelines and loads, running the
    // serve engine with a counting sink attached never perturbs the
    // event sequence
    use siam::serve::{poisson_arrivals, run, run_observed, EngineParams, EngineSink, Workload};

    #[derive(Default)]
    struct Counter {
        admitted: usize,
        completed: usize,
    }
    impl EngineSink for Counter {
        fn admitted(&mut self, _t: f64, _r: u32) {
            self.admitted += 1;
        }
        fn completed(&mut self, _t: f64, _r: u32, _l: f64) {
            self.completed += 1;
        }
    }

    check_property("engine_sink_bit_identical", 25, 0x0B5E, |rng| {
        let stages: Vec<f64> = (0..rng.range(1, 20)).map(|_| 1.0 + rng.f64() * 300.0).collect();
        let depth = rng.range(1, 5) as usize;
        let seed = rng.next_u64();
        let n = rng.range(10, 200) as usize;
        let bottleneck = stages.iter().cloned().fold(0.0f64, f64::max);
        let rate = (0.3 + 1.4 * rng.f64()) * 1.0e9 / bottleneck;
        let workload = || Workload::Open {
            arrivals: poisson_arrivals(rate, n, seed),
        };
        let plain = run(&stages, EngineParams { queue_depth: depth }, workload());
        let mut sink = Counter::default();
        let observed = run_observed(
            &stages,
            EngineParams { queue_depth: depth },
            workload(),
            None,
            &mut sink,
        );
        assert_eq!(plain.completed, observed.completed);
        assert_eq!(plain.dropped, observed.dropped);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&plain.latencies_ns), bits(&observed.latencies_ns));
        // open loop drains: every admitted request completes
        assert_eq!(sink.admitted, plain.completed);
        assert_eq!(sink.completed, plain.completed);
    });
}

#[test]
fn traced_serve_is_bit_identical_and_byte_deterministic() {
    let cfg = quick_serve_cfg();
    let plain = serve::serve(&cfg).unwrap();
    let (traced, trace_a) = serve::serve_traced(&cfg).unwrap();
    let (_, trace_b) = serve::serve_traced(&cfg).unwrap();
    assert_serve_identical(&plain, &traced);
    assert_trace_wellformed(&trace_a);
    // simulated-time timestamps only: two traced runs of the same
    // (config, seed) render the same bytes
    assert_eq!(trace_a.render(), trace_b.render(), "trace must be byte-deterministic");
    // request lifecycle shows up on the serve track
    let rendered = trace_a.render();
    for name in ["process_name", "admit", "serve", "complete"] {
        assert!(rendered.contains(name), "trace missing {name} events");
    }
}

#[test]
fn traced_failover_serve_records_fail_and_resume() {
    let cfg = quick_serve_cfg().with_spare_chiplets(1).with_failover(64, 0, 100.0);
    let plain = serve::serve(&cfg).unwrap();
    let (traced, trace) = serve::serve_traced(&cfg).unwrap();
    assert_serve_identical(&plain, &traced);
    assert!(traced.failover.is_some(), "failover scenario must report");
    let rendered = trace.render();
    assert!(rendered.contains("\"fail\""), "trace missing the failure instant");
    assert!(rendered.contains("\"resume\""), "trace missing the resume instant");
}

#[test]
fn traced_simulate_matches_plain_and_is_byte_deterministic() {
    let cfg = SiamConfig::paper_default().with_model("resnet20", "cifar10");
    let plain = coordinator::simulate(&cfg).unwrap();
    let ctx = SweepContext::new(&cfg).unwrap();
    let mut trace_a = TraceBuffer::new();
    let traced = coordinator::trace_point(&cfg, &ctx, &mut trace_a).unwrap();
    assert_sim_identical(&plain, &traced);
    assert_trace_wellformed(&trace_a);
    let ctx_b = SweepContext::new(&cfg).unwrap();
    let mut trace_b = TraceBuffer::new();
    coordinator::trace_point(&cfg, &ctx_b, &mut trace_b).unwrap();
    assert_eq!(trace_a.render(), trace_b.render(), "sim trace must be byte-deterministic");
    // stage occupancy: compute spans plus the epoch cache instants
    let rendered = trace_a.render();
    for name in ["compute", "inference", "epoch"] {
        assert!(rendered.contains(name), "sim trace missing {name} events");
    }
}

#[test]
fn profiled_simulate_is_bit_identical_and_records_stage_spans() {
    let cfg = SiamConfig::paper_default().with_model("resnet20", "cifar10");
    let plain = coordinator::simulate(&cfg).unwrap();
    let ctx = SweepContext::new(&cfg).unwrap();
    let prof = Profiler::new();
    let profiled = coordinator::run_point_profiled(&cfg, &ctx, true, Some(&prof)).unwrap();
    assert_sim_identical(&plain, &profiled);
    let labels: Vec<String> = prof.snapshot().into_iter().map(|(l, _)| l).collect();
    for stage in ["stage:dnn", "stage:mapping", "stage:circuit", "stage:noc", "stage:nop"] {
        assert!(labels.iter().any(|l| l == stage), "missing span {stage} in {labels:?}");
    }
    let j = prof.to_json();
    assert!(j.get("stage:circuit").and_then(|s| s.get("calls")).is_some());
}

#[test]
fn reports_carry_a_wellformed_meta_block() {
    let cfg = quick_serve_cfg();
    let serve_rep = serve::serve(&cfg).unwrap();
    let meta = serve_rep.meta.as_ref().expect("serve attaches meta");
    assert_eq!(meta.config_fingerprint.len(), 16);
    assert!(meta.epoch_cache.is_some() && meta.engine_tiers.is_some());

    let ctx = SweepContext::new(&cfg).unwrap();
    let mut sim_rep = coordinator::run_point_profiled(&cfg, &ctx, true, None).unwrap();
    assert!(sim_rep.meta.is_none(), "meta is attached by the front-end");
    coordinator::attach_meta(&cfg, &ctx, &mut sim_rep);

    for (what, json) in [("serve", serve_rep.to_json()), ("simulate", sim_rep.to_json())] {
        let m = json.get("meta").unwrap_or_else(|| panic!("{what} JSON missing meta"));
        for key in ["schema", "config_fingerprint", "model_source", "seeds", "wall_seconds"] {
            assert!(m.get(key).is_some(), "{what} meta missing {key}");
        }
        assert_eq!(m.get("schema").and_then(Json::as_str), Some("siam-meta/v1"));
    }
    // the same (config, seed) pins the same fingerprint
    let again = serve::serve(&cfg).unwrap();
    assert_eq!(
        again.meta.unwrap().config_fingerprint,
        meta.config_fingerprint,
        "fingerprint must be a pure function of the config"
    );
}

#[test]
fn log_level_parses_and_rejects() {
    assert_eq!(LogLevel::parse("quiet"), Some(LogLevel::Quiet));
    assert_eq!(LogLevel::parse("normal"), Some(LogLevel::Normal));
    assert_eq!(LogLevel::parse("verbose"), Some(LogLevel::Verbose));
    assert_eq!(LogLevel::parse("debug"), None);
}
