//! Runtime end-to-end tests: load the AOT-compiled Pallas crossbar
//! artifacts on the PJRT CPU client from Rust and validate numerics
//! against Rust-side oracles — the cross-language correctness proof of
//! the three-layer stack.
//!
//! Requires `make artifacts` (skipped gracefully if absent, but the CI
//! flow always builds them first).

use siam::runtime::{functional, Runtime};
use siam::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match Runtime::open(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime e2e ({e}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in [
        "xbar_gemm_64x128x64_adc4",
        "xbar_gemm_64x128x64_adc8",
        "xbar_gemm_256x256x128_adc8",
        "cnn_fwd_b4_adc4",
        "cnn_fwd_b4_adc8",
    ] {
        assert!(rt.find(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn lossless_crossbar_gemm_matches_exact_integer_gemm() {
    // 8-bit flash ADC covers the 128-row column current losslessly, so
    // the bit-serial crossbar must reproduce the exact integer product.
    let Some(rt) = runtime() else { return };
    let exe = rt.load("xbar_gemm_64x128x64_adc8").unwrap();
    let (m, k, n) = (64, 128, 64);
    for seed in [1u64, 7, 42] {
        let mut rng = Rng::new(seed);
        let (x, w) = functional::synth_gemm_inputs(&mut rng, m, k, n);
        let got = exe.run_f32(&[x.clone(), w.clone()]).unwrap();
        let want = functional::ref_gemm(&x, &w, m, k, n);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= 1.0, // fp32 reassociation on ~1e6 sums
                "seed {seed} elem {i}: crossbar {a} vs exact {b}"
            );
        }
    }
}

#[test]
fn lossy_adc_deviates_but_correlates() {
    let Some(rt) = runtime() else { return };
    let e4 = rt.load("xbar_gemm_64x128x64_adc4").unwrap();
    let (m, k, n) = (64, 128, 64);
    let mut rng = Rng::new(3);
    let (x, w) = functional::synth_gemm_inputs(&mut rng, m, k, n);
    let got = e4.run_f32(&[x.clone(), w.clone()]).unwrap();
    let want = functional::ref_gemm(&x, &w, m, k, n);
    // 4-bit ADC quantization must introduce real error...
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err > 10.0, "4-bit ADC should quantize ({max_err})");
    // ...but the outputs stay strongly correlated with the ideal GEMM
    let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
    let (mg, mw) = (mean(&got), mean(&want));
    let (mut num, mut dg, mut dw) = (0.0f64, 0.0f64, 0.0f64);
    for (a, b) in got.iter().zip(&want) {
        num += ((a - mg) * (b - mw)) as f64;
        dg += ((a - mg) * (a - mg)) as f64;
        dw += ((b - mw) * (b - mw)) as f64;
    }
    let corr = num / (dg.sqrt() * dw.sqrt());
    assert!(corr > 0.85, "correlation {corr}");
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("xbar_gemm_64x128x64_adc8").unwrap();
    // wrong arity
    assert!(exe.run_f32(&[vec![0.0; 64 * 128]]).is_err());
    // wrong element count
    assert!(exe
        .run_f32(&[vec![0.0; 64 * 128 + 1], vec![0.0; 128 * 64]])
        .is_err());
}

#[test]
fn unknown_artifact_is_a_clean_error() {
    let Some(rt) = runtime() else { return };
    let err = match rt.load("does_not_exist") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("loading a missing artifact must fail"),
    };
    assert!(err.contains("does_not_exist"), "{err}");
}

#[test]
fn functional_cnn_runs_and_adc_matters() {
    let Some(rt) = runtime() else { return };
    let r8 = functional::run_cnn(&rt, 8, 42).unwrap();
    let r4 = functional::run_cnn(&rt, 4, 42).unwrap();
    assert_eq!(r8.logits.len(), r8.batch * r8.classes);
    assert!(r8.logits.iter().all(|v| v.is_finite()));
    assert!(r4.logits.iter().all(|v| v.is_finite()));
    // same weights, different ADC resolution => different numerics
    let dev = r8
        .logits
        .iter()
        .zip(&r4.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(dev > 1e-3, "ADC resolution must affect the output ({dev})");
    // determinism: same seed, same result
    let r8b = functional::run_cnn(&rt, 8, 42).unwrap();
    assert_eq!(r8.logits, r8b.logits);
}

#[test]
fn gemm_scales_to_larger_tiles() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("xbar_gemm_256x256x128_adc8").unwrap();
    let (m, k, n) = (256, 256, 128);
    let mut rng = Rng::new(11);
    let (x, w) = functional::synth_gemm_inputs(&mut rng, m, k, n);
    let got = exe.run_f32(&[x.clone(), w.clone()]).unwrap();
    let want = functional::ref_gemm(&x, &w, m, k, n);
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    // K=256 spans two 128-row crossbars with digital (exact) accumulation
    assert!(max_err <= 2.0, "max err {max_err}");
}
