#!/usr/bin/env python3
"""Regenerate the binary corruption fixtures for tests/cache_corpus.rs.

Each fixture is a SIAM epoch-cache file (see rust/src/noc/store.rs for
the format) damaged in one specific way. The harness asserts the
documented recovery for every file, so any change here must be mirrored
in the expectations of cache_corpus.rs.

Run from this directory: python3 gen_fixtures.py
"""

import struct
from pathlib import Path

HERE = Path(__file__).resolve().parent

MAGIC = b"SIAMEPC1"
VERSION = 1
GENERATION = 1


def fnv1a(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x00000100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def header(generation: int = GENERATION) -> bytes:
    return MAGIC + struct.pack("<II", VERSION, 0) + struct.pack("<Q", generation)


def frame(payload: bytes) -> bytes:
    return struct.pack("<IQ", len(payload), fnv1a(payload)) + payload


def epoch(lo, hi, completion, packets, latency, hops, cf, per, ext, pf) -> bytes:
    return frame(
        b"\x00"
        + struct.pack("<10Q", lo, hi, completion, packets, latency, hops, cf, per, ext, pf)
    )


def point(lo, hi) -> bytes:
    return frame(b"\x01" + struct.pack("<QQ", lo, hi))


# the shared record set the harness knows by heart
A = epoch(0x11, 0x22, 100, 7, 350, 21, 5, 1, 1, 0)
B = epoch(0x33, 0x44, 200, 9, 900, 63, 9, 0, 0, 0)
C = epoch(0x77, 0x88, 300, 11, 1500, 99, 11, 0, 0, 0)
P = point(0x55, 0x66)
assert len(A) == len(B) == len(C) == 12 + 81
assert len(P) == 12 + 17

FIXTURES = {
    # a torn append: the last record stops mid-payload
    "truncated_tail.cache": header() + A + B + P + C[:40],
    # one flipped checksum byte on the final record
    "flipped_checksum.cache": header() + A + B + C[:4] + bytes([C[4] ^ 0xFF]) + C[5:],
    # a log written by an outdated simulator generation
    "stale_generation.cache": header(generation=0) + A + B,
    # an interrupted create: the file exists but holds nothing
    "zero_length.cache": b"",
    # a frame whose declared length runs past end-of-file
    "length_past_eof.cache": header() + A + struct.pack("<IQ", 81, 0xDEADBEEF) + b"\x00" * 10,
}

for name, data in FIXTURES.items():
    (HERE / name).write_bytes(data)
    print(f"{name}: {len(data)} bytes")
