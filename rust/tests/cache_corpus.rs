//! Corruption-recovery suite for the persistent epoch cache
//! (`siam::noc::EpochStore`).
//!
//! Fixtures live in `tests/cache_corpus/*.cache` — binary epoch-cache
//! files each damaged in one specific way (regenerate them with
//! `gen_fixtures.py` in the same directory). The recovery contract
//! under test is *a torn tail is data loss, never wrong results*: every
//! byte of corruption costs at most the records it touches, nothing
//! corrupt is ever replayed, and the repaired file reopens clean.
//!
//! `EpochStore::open` repairs files in place, so each test copies its
//! fixture into a scratch directory first — the checked-in corpus is
//! immutable.

use siam::noc::{EpochCache, EpochStore, LoadReport};
use std::path::PathBuf;

/// Frame overhead + payload of one epoch record, in bytes.
const EPOCH_RECORD: u64 = 12 + 81;
/// Frame overhead + payload of one point record, in bytes.
const POINT_RECORD: u64 = 12 + 17;
const HEADER: u64 = 24;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("cache_corpus")
        .join(name)
}

/// Copy `name` into a scratch path (open() repairs in place) and
/// return the copy's location.
fn scratch_copy(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("siam_cache_corpus_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let dst = dir.join(format!("{}_{}", std::process::id(), name));
    let _ = std::fs::remove_file(&dst);
    std::fs::copy(fixture(name), &dst)
        .unwrap_or_else(|e| panic!("copying fixture {name}: {e}"));
    dst
}

/// Open the damaged copy, assert the exact [`LoadReport`], then assert
/// the file was repaired: a reopen is clean (nothing further truncated,
/// same record counts) and the file has shrunk to `repaired_len`.
fn assert_recovery(name: &str, want: LoadReport, repaired_len: u64) {
    let path = scratch_copy(name);
    let (store, report) = EpochStore::open(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
    assert_eq!(report, want, "{name}: first-open load report");
    assert_eq!(store.epochs(), want.epochs_loaded, "{name}: epochs held");
    assert_eq!(store.points(), want.points_loaded, "{name}: points held");
    // hydration hands a cache exactly the surviving records — the
    // corrupt ones are gone, not garbled
    let cache = EpochCache::new();
    let fresh = store.hydrate(&cache);
    assert_eq!(fresh, want.epochs_loaded, "{name}: hydrated entries");
    assert_eq!(cache.len(), want.epochs_loaded);
    assert_eq!(cache.hydrated(), want.epochs_loaded as u64);
    assert_eq!((cache.hits(), cache.misses()), (0u64, 0u64), "{name}: hydration is not traffic");
    drop(store);
    assert_eq!(
        std::fs::metadata(&path).unwrap().len(),
        repaired_len,
        "{name}: repaired file length"
    );
    let (store, second) = EpochStore::open(&path).unwrap();
    assert_eq!(second.truncated_bytes, 0, "{name}: reopen must be clean");
    assert!(!second.stale_generation, "{name}: repaired generation is current");
    assert_eq!(second.epochs_loaded, want.epochs_loaded, "{name}: reopen epochs");
    assert_eq!(second.points_loaded, want.points_loaded, "{name}: reopen points");
    drop(store);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corpus_is_populated() {
    for name in [
        "truncated_tail.cache",
        "flipped_checksum.cache",
        "stale_generation.cache",
        "zero_length.cache",
        "length_past_eof.cache",
    ] {
        assert!(fixture(name).exists(), "missing fixture {name}");
    }
}

#[test]
fn truncated_tail_loses_only_the_torn_record() {
    // header + 2 epochs + 1 point + 40 bytes of a torn epoch append:
    // everything before the tear survives, the tear is discarded
    assert_recovery(
        "truncated_tail.cache",
        LoadReport {
            epochs_loaded: 2,
            points_loaded: 1,
            duplicate_records: 0,
            truncated_bytes: 40,
            stale_generation: false,
        },
        HEADER + 2 * EPOCH_RECORD + POINT_RECORD,
    );
}

#[test]
fn flipped_checksum_byte_drops_the_record_not_the_file() {
    // the third record's checksum was flipped: its payload bytes are
    // intact but unverifiable, so it must be dropped — replaying a
    // record that fails its checksum would risk wrong epoch results
    assert_recovery(
        "flipped_checksum.cache",
        LoadReport {
            epochs_loaded: 2,
            points_loaded: 0,
            duplicate_records: 0,
            truncated_bytes: EPOCH_RECORD,
            stale_generation: false,
        },
        HEADER + 2 * EPOCH_RECORD,
    );
}

#[test]
fn stale_generation_discards_the_whole_log() {
    // generation 0 log under a generation-1 reader: every record was
    // produced by incompatible simulator semantics, so none may be
    // replayed — the file resets to a fresh current-generation header
    assert_recovery(
        "stale_generation.cache",
        LoadReport {
            epochs_loaded: 0,
            points_loaded: 0,
            duplicate_records: 0,
            truncated_bytes: 2 * EPOCH_RECORD,
            stale_generation: true,
        },
        HEADER,
    );
}

#[test]
fn zero_length_file_is_initialised_in_place() {
    // an interrupted create left an empty file: treated like a missing
    // one — fresh header, nothing lost because nothing existed
    assert_recovery("zero_length.cache", LoadReport::default(), HEADER);
}

#[test]
fn length_past_eof_truncates_at_the_last_valid_record() {
    // the second frame claims an 81-byte payload but the file ends 10
    // bytes in: the frame (and its 10 orphan bytes) are discarded
    assert_recovery(
        "length_past_eof.cache",
        LoadReport {
            epochs_loaded: 1,
            points_loaded: 0,
            duplicate_records: 0,
            truncated_bytes: 12 + 10,
            stale_generation: false,
        },
        HEADER + EPOCH_RECORD,
    );
}

#[test]
fn recovered_files_accept_new_appends() {
    // recovery must leave a healthy log: appending a point fingerprint
    // after repair and reopening keeps every prior record plus the new
    // one (the repaired tail is a valid record boundary)
    let path = scratch_copy("truncated_tail.cache");
    let (store, _) = EpochStore::open(&path).unwrap();
    assert!(store.record_point((0xAB, 0xCD)).unwrap());
    assert!(!store.record_point((0xAB, 0xCD)).unwrap(), "second write is a no-op");
    drop(store);
    let (store, report) = EpochStore::open(&path).unwrap();
    assert_eq!(report.truncated_bytes, 0);
    assert_eq!(report.epochs_loaded, 2);
    assert_eq!(report.points_loaded, 2, "the old and the new point");
    assert!(store.known_point((0xAB, 0xCD)));
    assert!(store.known_point((0x55, 0x66)), "the fixture's point survived");
    drop(store);
    let _ = std::fs::remove_file(&path);
}
