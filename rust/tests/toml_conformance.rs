//! toml-test-style conformance suite for the in-tree TOML-subset
//! parser (`siam::config::parse_flat`).
//!
//! Fixtures live in `tests/toml_corpus/{valid,invalid}/*.toml`. Each
//! fixture carries its expectations as `# expect-...` comment
//! annotations (comments are inert to the parser, so the annotations
//! ride inside the input they describe):
//!
//! * valid:   `# expect-count: N`, `# expect-key: K`,
//!   `# expect-line: K = N`, `# expect-int|float|str|bool: K = V`,
//!   `# expect-len: K = N` (array length), `# expect-config-ok`
//!   (the full `SiamConfig::from_toml_str` pipeline must accept it too)
//! * invalid: `# expect-error-line: N` (the error message must cite
//!   that line), `# expect-error-contains: TEXT` (repeatable)
//!
//! Invalid fixtures may fail at any layer: `parse_flat` itself, the
//! unknown-key / bad-value checks in `apply`, or semantic validation —
//! the harness feeds survivors of each layer to the next and asserts
//! *something* rejects them with the annotated message.

use siam::config::{parse_flat, SiamConfig, Value};
use std::collections::BTreeMap;
use std::path::PathBuf;

fn corpus(kind: &str) -> Vec<(String, String)> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("toml_corpus")
        .join(kind);
    let mut out: Vec<(String, String)> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()))
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
        .map(|p| {
            let name = p.file_name().unwrap().to_string_lossy().into_owned();
            let text = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("reading {}: {e}", p.display()));
            (name, text)
        })
        .collect();
    out.sort();
    out
}

/// `# expect-xxx: rest` annotation lines of a fixture.
fn annotations<'a>(text: &'a str, tag: &str) -> Vec<&'a str> {
    let prefix = format!("# expect-{tag}:");
    text.lines()
        .filter_map(|l| l.trim().strip_prefix(&prefix))
        .map(str::trim)
        .collect()
}

/// Split a `KEY = VALUE` annotation (VALUE may be empty: `KEY =`).
fn key_value(ann: &str) -> (&str, &str) {
    ann.split_once(" = ")
        .map(|(k, v)| (k.trim(), v))
        .or_else(|| ann.strip_suffix(" =").map(|k| (k.trim(), "")))
        .unwrap_or_else(|| panic!("malformed 'KEY = VALUE' annotation: '{ann}'"))
}

fn lookup<'a>(
    map: &'a BTreeMap<String, (Value, usize)>,
    key: &str,
    fixture: &str,
) -> &'a (Value, usize) {
    map.get(key).unwrap_or_else(|| {
        panic!("{fixture}: expected key '{key}', parsed keys: {:?}", map.keys())
    })
}

#[test]
fn corpus_is_populated() {
    // the suite only means something at toml-test scale
    assert!(corpus("valid").len() >= 47, "valid corpus shrank");
    assert!(corpus("invalid").len() >= 34, "invalid corpus shrank");
}

#[test]
fn valid_corpus() {
    for (name, text) in corpus("valid") {
        let map = parse_flat(&text)
            .unwrap_or_else(|e| panic!("{name}: valid fixture rejected: {e}"));

        for ann in annotations(&text, "count") {
            let want: usize = ann.parse().expect("expect-count number");
            assert_eq!(map.len(), want, "{name}: flat entry count");
        }
        for ann in annotations(&text, "key") {
            lookup(&map, ann, &name);
        }
        for ann in annotations(&text, "line") {
            let (k, v) = key_value(ann);
            let want: usize = v.parse().expect("expect-line number");
            assert_eq!(lookup(&map, k, &name).1, want, "{name}: line of '{k}'");
        }
        for ann in annotations(&text, "int") {
            let (k, v) = key_value(ann);
            let want: i64 = v.parse().expect("expect-int number");
            match &lookup(&map, k, &name).0 {
                Value::Int(i) => assert_eq!(*i, want, "{name}: value of '{k}'"),
                other => panic!("{name}: '{k}' is {other:?}, expected Int"),
            }
        }
        for ann in annotations(&text, "float") {
            let (k, v) = key_value(ann);
            let want: f64 = v.parse().expect("expect-float number");
            match &lookup(&map, k, &name).0 {
                Value::Float(f) => assert_eq!(*f, want, "{name}: value of '{k}'"),
                other => panic!("{name}: '{k}' is {other:?}, expected Float"),
            }
        }
        for ann in annotations(&text, "str") {
            let (k, v) = key_value(ann);
            match &lookup(&map, k, &name).0 {
                Value::Str(s) => assert_eq!(s, v, "{name}: value of '{k}'"),
                other => panic!("{name}: '{k}' is {other:?}, expected Str"),
            }
        }
        for ann in annotations(&text, "bool") {
            let (k, v) = key_value(ann);
            let want: bool = v.parse().expect("expect-bool value");
            match &lookup(&map, k, &name).0 {
                Value::Bool(b) => assert_eq!(*b, want, "{name}: value of '{k}'"),
                other => panic!("{name}: '{k}' is {other:?}, expected Bool"),
            }
        }
        for ann in annotations(&text, "len") {
            let (k, v) = key_value(ann);
            let want: usize = v.parse().expect("expect-len number");
            let got = match &lookup(&map, k, &name).0 {
                Value::Array(a) => a.len(),
                Value::StrArray(a) => a.len(),
                other => panic!("{name}: '{k}' is {other:?}, expected an array"),
            };
            assert_eq!(got, want, "{name}: length of '{k}'");
        }
        if text.lines().any(|l| l.trim() == "# expect-config-ok") {
            SiamConfig::from_toml_str(&text)
                .unwrap_or_else(|e| panic!("{name}: full config pipeline rejected: {e:#}"));
        }
    }
}

#[test]
fn invalid_corpus() {
    for (name, text) in corpus("invalid") {
        // the parse layer first; survivors go through the full pipeline
        // (apply's unknown-key / bad-value checks, then validation)
        let err = match parse_flat(&text) {
            Err(e) => e,
            Ok(_) => match SiamConfig::from_toml_str(&text) {
                Err(e) => format!("{e:#}"),
                Ok(_) => panic!("{name}: invalid fixture accepted end to end"),
            },
        };
        for ann in annotations(&text, "error-line") {
            let n: usize = ann.parse().expect("expect-error-line number");
            assert!(
                err.contains(&format!("line {n}:")),
                "{name}: error must cite line {n}, got: {err}"
            );
        }
        for ann in annotations(&text, "error-contains") {
            assert!(err.contains(ann), "{name}: error must contain '{ann}', got: {err}");
        }
        assert!(
            !annotations(&text, "error-line").is_empty()
                || !annotations(&text, "error-contains").is_empty(),
            "{name}: invalid fixture carries no expectations"
        );
    }
}
