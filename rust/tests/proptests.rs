//! Property-based tests on coordinator/engine invariants, driven by the
//! in-tree deterministic RNG harness (`siam::util::check_property` —
//! the offline build vendors no proptest).

use siam::config::SiamConfig;
use siam::dnn::build_model;
use siam::mapping::{build_traffic, map_dnn, Flow, Placement};
use siam::noc::{FlitSim, FlowSim, Mesh, PacketSim};
use siam::util::{check_property, Rng};

const MODELS: &[(&str, &str)] = &[
    ("lenet5", "cifar10"),
    ("nin", "cifar10"),
    ("resnet20", "cifar10"),
    ("resnet56", "cifar10"),
    ("resnet110", "cifar10"),
    ("drivenet", "drivenet"),
];

fn random_cfg(rng: &mut Rng) -> SiamConfig {
    let mut cfg = SiamConfig::paper_default();
    cfg.chiplet.xbar_rows = 1 << rng.range(5, 8); // 32..256
    cfg.chiplet.xbar_cols = 1 << rng.range(5, 8);
    cfg.chiplet.tiles_per_chiplet = rng.range(2, 36) as usize;
    cfg.chiplet.xbars_per_tile = [4, 8, 16][rng.below(3) as usize];
    cfg.chiplet.cols_per_adc = [4, 8][rng.below(2) as usize];
    // keep cols_per_adc dividing xbar_cols (both powers of two >= 4)
    cfg.dnn.weight_precision = [4, 8, 16][rng.below(3) as usize];
    cfg.device.bits_per_cell = [1, 2][rng.below(2) as usize];
    cfg.validate().expect("generated config must be valid");
    cfg
}

#[test]
fn mapping_invariants_hold_for_random_configs() {
    check_property("mapping_invariants", 40, 0xA11CE, |rng| {
        let (model, ds) = MODELS[rng.below(MODELS.len() as u64) as usize];
        let cfg = random_cfg(rng);
        let dnn = build_model(model, ds).unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let s = cfg.chiplet_size_xbars();

        // 1. every weight layer mapped, share sums match totals
        assert_eq!(map.per_layer.len(), dnn.weight_layers().len());
        for lm in &map.per_layer {
            let sum: usize = lm.chiplets.iter().map(|c| c.xbars).sum();
            assert_eq!(sum, lm.xbars);
            assert_eq!(lm.xbars, lm.rows * lm.cols);
            assert!(lm.cell_utilization > 0.0 && lm.cell_utilization <= 1.0);
            // 2. uniform split: imbalance <= 1 crossbar
            if lm.spans_chiplets() {
                let min = lm.chiplets.iter().map(|c| c.xbars).min().unwrap();
                let max = lm.chiplets.iter().map(|c| c.xbars).max().unwrap();
                assert!(max - min <= 1);
            }
        }
        // 3. no chiplet over capacity; used counts consistent
        let mut used = vec![0usize; map.num_chiplets];
        for lm in &map.per_layer {
            for sh in &lm.chiplets {
                used[sh.chiplet] += sh.xbars;
            }
        }
        for (c, (&got, &want)) in used.iter().zip(&map.chiplet_used_xbars).enumerate() {
            assert_eq!(got, want, "chiplet {c} usage mismatch");
            assert!(got <= s, "chiplet {c} over capacity");
        }
        // 4. utilization in (0, 1]
        let u = map.xbar_utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization {u}");
    });
}

#[test]
fn traffic_flows_are_wellformed() {
    check_property("traffic_wellformed", 25, 0xBEEF, |rng| {
        let (model, ds) = MODELS[rng.below(MODELS.len() as u64) as usize];
        let cfg = random_cfg(rng);
        let dnn = build_model(model, ds).unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let t = build_traffic(&dnn, &map, &pl, &cfg);

        let nodes = pl.nodes() as u32;
        for ep in &t.nop_epochs {
            for f in &ep.flows {
                assert!(f.src < nodes && f.dst < nodes, "NoP node out of range");
                assert_ne!(f.src, f.dst, "self-loop flow");
                assert!(f.count > 0 && f.stride > 0);
            }
        }
        let tiles = cfg.chiplet.tiles_per_chiplet as u32;
        for ep in &t.noc_epochs {
            assert!(ep.chiplet < map.num_chiplets);
            for f in &ep.flows {
                assert!(f.src < tiles && f.dst < tiles, "tile out of range");
                assert_ne!(f.src, f.dst);
            }
        }
        // volumes are non-negative and consistent with epochs
        assert!(t.intra_chiplet_bits >= 0.0);
        if t.nop_epochs.is_empty() {
            assert_eq!(t.accumulator_adds, 0);
        }
    });
}

#[test]
fn packet_sim_conserves_packets_and_orders_flows() {
    check_property("packet_conservation", 30, 0xC0FFEE, |rng| {
        let n = rng.range(4, 36) as usize;
        let mesh = Mesh::new(n);
        let mut flows = Vec::new();
        for _ in 0..rng.range(1, 20) {
            let src = rng.below(n as u64) as u32;
            let dst = rng.below(n as u64) as u32;
            if src == dst {
                continue;
            }
            flows.push(Flow {
                src,
                dst,
                count: rng.range(1, 200),
                start: rng.below(16),
                stride: rng.range(1, 8),
            });
        }
        let want: u64 = flows.iter().map(|f| f.count).sum();
        let res = PacketSim::new(&mesh).run(&flows);
        // 1. conservation
        assert_eq!(res.packets, want);
        // 2. completion bounds: at least the busiest link's serialization,
        //    at most fully-serialized whole trace
        if want > 0 {
            assert!(res.completion_cycles >= 1);
            let max_span: u64 = flows
                .iter()
                .map(|f| f.start + (f.count - 1) * f.stride + 1)
                .max()
                .unwrap_or(0);
            let bound = max_span
                + want * (mesh.width + mesh.height) as u64 * 4
                + 4 * (mesh.width + mesh.height) as u64;
            assert!(
                res.completion_cycles <= bound,
                "completion {} > bound {bound}",
                res.completion_cycles
            );
            // 3. avg latency at least the minimum hop pipeline
            assert!(res.avg_latency() >= 1.0);
        }
    });
}

#[test]
fn packet_sim_tracks_flit_sim_on_random_small_traces() {
    check_property("packet_vs_flit", 12, 0xD1CE, |rng| {
        let mesh = Mesh::new(9 + rng.below(8) as usize);
        let mut flows = Vec::new();
        for _ in 0..rng.range(1, 6) {
            let src = rng.below(mesh.nodes() as u64) as u32;
            let dst = rng.below(mesh.nodes() as u64) as u32;
            if src == dst {
                continue;
            }
            flows.push(Flow {
                src,
                dst,
                count: rng.range(5, 40),
                start: rng.below(4),
                stride: rng.range(1, 4),
            });
        }
        if flows.is_empty() {
            return;
        }
        let p = PacketSim::new(&mesh).run(&flows);
        let f = FlitSim::new(&mesh, 16).run(&flows);
        assert_eq!(p.packets, f.packets, "packet conservation differs");
        let rel = (p.completion_cycles as f64 - f.completion_cycles as f64).abs()
            / f.completion_cycles.max(1) as f64;
        assert!(
            rel < 0.5,
            "packet {} vs flit {} (rel {rel:.2})",
            p.completion_cycles,
            f.completion_cycles
        );
    });
}

/// Random Algorithm-2-shaped epoch: one shared stride, all starts inside
/// the first round, positive counts — the uniform-trace contract of the
/// flow-level engine.
fn random_uniform_trace(rng: &mut Rng, nodes: usize, max_flows: u64, max_count: u64) -> Vec<Flow> {
    let stride = rng.range(1, 8);
    let mut flows = Vec::new();
    for _ in 0..rng.range(1, max_flows) {
        let src = rng.below(nodes as u64) as u32;
        let dst = rng.below(nodes as u64) as u32;
        if src == dst {
            continue;
        }
        flows.push(Flow {
            src,
            dst,
            count: rng.range(1, max_count),
            start: rng.below(stride),
            stride,
        });
    }
    flows
}

#[test]
fn flow_sim_is_exactly_packet_sim_on_uniform_traces() {
    // Tentpole regression: on Algorithm-2 (uniform) epochs the flow-level
    // engine must reproduce the brute-force per-packet schedule
    // bit-for-bit — closed forms, certificates and fallbacks included.
    check_property("flow_vs_packet_exact", 60, 0xF10775, |rng| {
        let nodes = rng.range(4, 16) as usize;
        let mesh = Mesh::new(nodes);
        let flows = random_uniform_trace(rng, nodes, 64, 150);
        let got = FlowSim::new(&mesh).run(&flows);
        let mut brute = PacketSim::new(&mesh);
        brute.extrapolate = false;
        let want = brute.run(&flows);
        assert_eq!(got, want, "flow-level diverged on {} flows", flows.len());
    });
}

#[test]
fn flow_sim_arena_reuse_is_exact_across_epochs() {
    // one engine instance over many epochs (the sweep usage pattern)
    // must match fresh per-epoch engines exactly
    check_property("flow_arena_reuse", 10, 0xA3E4A, |rng| {
        let nodes = rng.range(4, 16) as usize;
        let mesh = Mesh::new(nodes);
        let mut shared = FlowSim::new(&mesh);
        for _ in 0..8 {
            let flows = random_uniform_trace(rng, nodes, 32, 80);
            let warm = shared.run(&flows);
            let cold = FlowSim::new(&mesh).run(&flows);
            assert_eq!(warm, cold, "arena state leaked between epochs");
        }
    });
}

#[test]
fn flow_sim_matches_packet_sim_on_irregular_traces() {
    // mixed strides / late starts: the engine must delegate wholesale to
    // the per-packet scheduler and therefore agree with it exactly
    check_property("flow_vs_packet_irregular", 20, 0x1DE9A1, |rng| {
        let nodes = rng.range(4, 16) as usize;
        let mesh = Mesh::new(nodes);
        let mut flows = Vec::new();
        for _ in 0..rng.range(1, 16) {
            let src = rng.below(nodes as u64) as u32;
            let dst = rng.below(nodes as u64) as u32;
            if src == dst {
                continue;
            }
            flows.push(Flow {
                src,
                dst,
                count: rng.range(1, 60),
                start: rng.below(16),
                stride: rng.range(1, 6),
            });
        }
        let got = FlowSim::new(&mesh).run(&flows);
        let want = PacketSim::new(&mesh).run(&flows);
        assert_eq!(got, want);
    });
}

#[test]
fn flow_sim_tracks_flit_sim_on_random_small_traces() {
    // under contention the list-scheduling tiers approximate the golden
    // flit-level model within the documented tolerance
    check_property("flow_vs_flit", 12, 0xF117, |rng| {
        let nodes = 9 + rng.below(8) as usize;
        let mesh = Mesh::new(nodes);
        let flows = random_uniform_trace(rng, nodes, 6, 40);
        if flows.is_empty() {
            return;
        }
        let p = FlowSim::new(&mesh).run(&flows);
        let f = FlitSim::new(&mesh, 16).run(&flows);
        assert_eq!(p.packets, f.packets, "packet conservation differs");
        let rel = (p.completion_cycles as f64 - f.completion_cycles as f64).abs()
            / f.completion_cycles.max(1) as f64;
        assert!(
            rel < 0.5,
            "flow {} vs flit {} (rel {rel:.2})",
            p.completion_cycles,
            f.completion_cycles
        );
    });
}

#[test]
fn serve_engine_is_seed_deterministic() {
    // same seed => bit-identical percentiles and throughput, for random
    // serving configurations over random synthetic stage pipelines
    use siam::serve::{poisson_arrivals, run, EngineParams, Workload};
    check_property("serve_seed_deterministic", 30, 0x5E4E, |rng| {
        let stages: Vec<f64> = (0..rng.range(1, 40))
            .map(|_| 1.0 + rng.f64() * 500.0)
            .collect();
        let depth = rng.range(1, 6) as usize;
        let seed = rng.next_u64();
        let n = rng.range(10, 300) as usize;
        let bottleneck = stages.iter().cloned().fold(0.0f64, f64::max);
        let rate = (0.2 + 1.6 * rng.f64()) * 1.0e9 / bottleneck; // 0.2x..1.8x
        let once = || {
            let w = Workload::Open {
                arrivals: poisson_arrivals(rate, n, seed),
            };
            run(&stages, EngineParams { queue_depth: depth }, w)
        };
        let (a, b) = (once(), once());
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.latencies_ns), bits(&b.latencies_ns));
        assert_eq!(
            a.steady_throughput_qps().to_bits(),
            b.steady_throughput_qps().to_bits()
        );
        // conservation and sanity under any load
        assert_eq!(a.completed + a.dropped, n);
        let single_pass: f64 = stages.iter().sum();
        assert!(a.latencies_ns.iter().all(|&l| l >= single_pass - 1e-6));
    });
}

#[test]
fn serve_full_pipeline_percentiles_reproduce() {
    // end to end (mapping -> engines -> stage graph -> event loop): the
    // same seed yields bit-identical percentiles across fresh contexts
    let mut cfg = SiamConfig::paper_default().with_model("lenet5", "cifar10");
    cfg.serve.requests = 200;
    for seed in [1u64, 0xDEAD_BEEF] {
        cfg.serve.seed = seed;
        let a = siam::serve::serve(&cfg).unwrap();
        let b = siam::serve::serve(&cfg).unwrap();
        assert_eq!(a.p50_ms.to_bits(), b.p50_ms.to_bits());
        assert_eq!(a.p95_ms.to_bits(), b.p95_ms.to_bits());
        assert_eq!(a.p99_ms.to_bits(), b.p99_ms.to_bits());
        assert_eq!(a.throughput_qps.to_bits(), b.throughput_qps.to_bits());
    }
}

#[test]
fn dram_subset_estimator_bounded_error() {
    check_property("dram_subset_error", 20, 0x5EED, |rng| {
        let bytes = (rng.range(64, 4096) * 64) as usize;
        let full = siam::dram::estimate_with(
            bytes,
            &siam::config::DramConfig {
                kind: siam::config::DramKind::Ddr4,
                bus_bits: 64,
                subset_fraction: 1.0,
            },
        );
        let frac = 0.25 + 0.5 * rng.f64();
        let sub = siam::dram::estimate_with(
            bytes,
            &siam::config::DramConfig {
                kind: siam::config::DramKind::Ddr4,
                bus_bits: 64,
                subset_fraction: frac,
            },
        );
        let err = (sub.edp() - full.edp()).abs() / full.edp();
        // Fig. 7a: extrapolation error stays small for >=25% subsets
        assert!(err < 0.10, "EDP error {err:.3} at fraction {frac:.2}");
    });
}

#[test]
fn cost_model_monotone_in_area() {
    check_property("cost_monotone", 50, 0xFACE, |rng| {
        let m = siam::cost::CostModel::default();
        let a = 5.0 + rng.f64() * 500.0;
        let b = a + 1.0 + rng.f64() * 100.0;
        assert!(
            m.normalized_die_cost(b) > m.normalized_die_cost(a),
            "cost must grow with area: {a} vs {b}"
        );
        assert!(m.yield_of(b) < m.yield_of(a));
    });
}

#[test]
fn fault_injection_invariants_hold_for_random_draws() {
    use siam::config::FaultConfig;
    use siam::fault::inject;
    check_property("fault_injection_invariants", 40, 0xFA017, |rng| {
        let n = rng.range(2, 40) as usize;
        let caps: Vec<usize> = (0..n).map(|_| rng.range(16, 512) as usize).collect();
        let mut kills: Vec<usize> =
            (0..rng.below(4)).map(|_| rng.below(n as u64) as usize).collect();
        kills.sort_unstable();
        kills.dedup();
        let fc = FaultConfig {
            kill_chiplets: kills.clone(),
            die_yield: 0.7 + 0.3 * rng.f64(), // [0.7, 1.0)
            xbar_fault_fraction: 0.2 * rng.f64(), // [0, 0.2)
            seed: rng.next_u64(),
        };
        let a = inject(&fc, &caps).unwrap();
        // 1. bit-determinism in the seed
        assert_eq!(a, inject(&fc, &caps).unwrap(), "same seed must draw the same faults");
        // 2. dead list sorted, deduped, kill list included
        assert!(a.dead_chiplets.windows(2).all(|w| w[0] < w[1]), "dead ids not ascending");
        for k in &kills {
            assert!(a.dead_chiplets.contains(k), "explicit kill {k} missing");
        }
        // 3. per-chiplet faults bounded by capacity; dead lose everything
        assert_eq!(a.faulty_xbars.len(), n);
        for (c, (&f, &cap)) in a.faulty_xbars.iter().zip(&caps).enumerate() {
            assert!(f <= cap, "chiplet {c}: {f} faulty > capacity {cap}");
            assert_eq!(a.effective_capacity(c, cap), cap - f);
        }
        for &d in &a.dead_chiplets {
            assert_eq!(a.faulty_xbars[d], caps[d], "dead chiplet {d} must lose its capacity");
        }
        assert_eq!(
            a.is_clean(),
            a.dead_chiplets.is_empty() && a.faulty_xbars.iter().all(|&f| f == 0)
        );
    });
}

#[test]
fn fault_remap_repacks_every_layer_onto_surviving_capacity() {
    use siam::fault::{inject, map_dnn_with_faults};
    use siam::mapping::MappingError;
    check_property("fault_remap_coverage", 25, 0xDEAD5, |rng| {
        let (model, ds) = MODELS[rng.below(MODELS.len() as u64) as usize];
        let dnn = build_model(model, ds).unwrap();
        let mut cfg = SiamConfig::paper_default();
        cfg.system.spare_chiplets = rng.range(1, 3) as usize;
        cfg.fault.seed = rng.next_u64();
        cfg.fault.xbar_fault_fraction = 0.1 * rng.f64();
        let total = map_dnn(&dnn, &cfg).unwrap().num_chiplets + cfg.system.spare_chiplets;
        let mut kills: Vec<usize> =
            (0..rng.below(3)).map(|_| rng.below(total as u64) as usize).collect();
        kills.sort_unstable();
        kills.dedup();
        cfg.fault.kill_chiplets = kills;
        match map_dnn_with_faults(&dnn, &cfg) {
            Ok((map, rep)) => {
                let state = inject(&cfg.fault, &map.chiplet_capacities).unwrap();
                // 1. full coverage: every layer keeps all its crossbars,
                //    none of them on a dead chiplet
                for lm in &map.per_layer {
                    let sum: usize = lm.chiplets.iter().map(|s| s.xbars).sum();
                    assert_eq!(sum, lm.xbars, "layer lost crossbars in the remap");
                    for s in &lm.chiplets {
                        assert!(
                            !state.dead_chiplets.contains(&s.chiplet),
                            "share on dead chiplet {}",
                            s.chiplet
                        );
                    }
                }
                // 2. bookkeeping consistent and within surviving capacity
                let mut used = vec![0usize; map.num_chiplets];
                for lm in &map.per_layer {
                    for s in &lm.chiplets {
                        used[s.chiplet] += s.xbars;
                    }
                }
                assert_eq!(used, map.chiplet_used_xbars);
                for (c, &u) in used.iter().enumerate() {
                    assert!(
                        u <= state.effective_capacity(c, map.chiplet_capacities[c]),
                        "chiplet {c} packed beyond its surviving capacity"
                    );
                }
                assert_eq!(rep.remapped, !state.is_clean());
            }
            // over-killed configurations must fail loudly, not drop layers
            Err(MappingError::InsufficientSurvivingCapacity { needed_xbars, available_xbars }) => {
                assert!(available_xbars < needed_xbars);
            }
            Err(e) => panic!("unexpected mapping error: {e:?}"),
        }
    });
}

#[test]
fn zero_fault_remap_is_the_identity_for_random_configs() {
    // the bit-identity tentpole pin, generalized: with nothing injected
    // and no spares, the fault-aware mapper must return exactly the
    // classic mapping for any valid geometry
    use siam::fault::map_dnn_with_faults;
    check_property("zero_fault_identity", 20, 0x1DE47, |rng| {
        let (model, ds) = MODELS[rng.below(MODELS.len() as u64) as usize];
        let cfg = random_cfg(rng);
        let dnn = build_model(model, ds).unwrap();
        let baseline = map_dnn(&dnn, &cfg).unwrap();
        let (map, rep) = map_dnn_with_faults(&dnn, &cfg).unwrap();
        assert!(!rep.remapped);
        assert!(rep.dead_chiplets.is_empty());
        assert_eq!(rep.lost_capacity_xbars, 0);
        assert_eq!(map.num_chiplets, baseline.num_chiplets);
        assert_eq!(map.chiplet_used_xbars, baseline.chiplet_used_xbars);
        for (a, b) in map.per_layer.iter().zip(&baseline.per_layer) {
            assert_eq!(a.chiplets, b.chiplets, "identity remap moved a layer");
        }
    });
}

#[test]
fn fault_and_variation_seed_streams_are_isolated() {
    // the two reliability subsystems own independent SplitMix64 streams:
    // enabling [variation] must not shift a single fault draw, and
    // enabling [fault] must not shift a single variation draw. The
    // Monte-Carlo accuracy statistics depend only on the variation
    // stream and the per-layer crossbar counts — which a fault remap
    // preserves — so they pin the converse direction end to end.
    use siam::config::FaultConfig;
    use siam::coordinator::simulate;
    check_property("fault_variation_stream_isolation", 8, 0x150A7E, |rng| {
        let mut cfg = SiamConfig::paper_default().with_model("lenet5", "cifar10");
        cfg.system.spare_chiplets = 1;
        cfg.fault.xbar_fault_fraction = 0.05 * rng.f64();
        cfg.fault.seed = rng.next_u64();
        let mut noisy = cfg.clone();
        noisy.variation.sigma_program = 0.02 + 0.1 * rng.f64();
        noisy.variation.drift_nu = 0.05 * rng.f64();
        noisy.variation.drift_time_s = 1.0e3;
        noisy.variation.mc_samples = 8;
        noisy.variation.seed = rng.next_u64();

        // [variation] on vs absent: fault injection draws bit-identically
        let plain = simulate(&cfg).unwrap();
        let var = simulate(&noisy).unwrap();
        assert!(plain.variation.is_none() && var.variation.is_some());
        assert_eq!(plain.fault, var.fault, "variation shifted the fault stream");

        // [fault] on vs absent: the Monte-Carlo draws are bit-identical
        let mut unfaulted = noisy.clone();
        unfaulted.system.spare_chiplets = 0;
        unfaulted.fault = FaultConfig::default();
        let v_clean = simulate(&unfaulted).unwrap().variation.unwrap();
        let v_fault = var.variation.unwrap();
        for (a, b, what) in [
            (v_clean.accuracy_proxy_mean, v_fault.accuracy_proxy_mean, "accuracy mean"),
            (v_clean.accuracy_proxy_ci95, v_fault.accuracy_proxy_ci95, "accuracy CI"),
            (v_clean.drift_shift_ln_mean, v_fault.drift_shift_ln_mean, "drift shift"),
            (v_clean.drift_energy_factor, v_fault.drift_energy_factor, "drift factor"),
        ] {
            assert_eq!(a.to_bits(), b.to_bits(), "faults shifted the variation stream: {what}");
        }
    });
}

#[test]
fn metrics_composition_laws() {
    check_property("metrics_laws", 50, 0xABCD, |rng| {
        let m1 = siam::Metrics::new(rng.f64() * 100.0, rng.f64() * 100.0, rng.f64() * 100.0);
        let m2 = siam::Metrics::new(rng.f64() * 100.0, rng.f64() * 100.0, rng.f64() * 100.0);
        let serial = m1.then(&m2);
        let parallel = m1.alongside(&m2);
        assert!(serial.latency_ns >= parallel.latency_ns);
        assert!((serial.energy_pj - parallel.energy_pj).abs() < 1e-9);
        assert!((serial.area_um2 - parallel.area_um2).abs() < 1e-9);
        let r = m1.replicate(3);
        assert!((r.area_um2 - 3.0 * m1.area_um2).abs() < 1e-9);
        assert!((r.latency_ns - m1.latency_ns).abs() < 1e-9);
    });
}

// ---------------------------------------------------------------------------
// persistent epoch cache (noc::store) + pruned sweep searches
// ---------------------------------------------------------------------------

/// Scratch path for one persistent-cache property case, unique per
/// process and call.
fn cache_scratch(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join("siam_proptest_cache");
    std::fs::create_dir_all(&dir).unwrap();
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    let path = dir.join(format!("{tag}_{}_{n}.cache", std::process::id()));
    let _ = std::fs::remove_file(&path);
    path
}

#[test]
fn cache_file_round_trips_sweeps_bit_identically() {
    use siam::coordinator::SweepBuilder;
    // write-then-load over randomized epoch batches, through the public
    // surface: a cold sweep persists its epochs, a warm re-run replays
    // them from disk — and every report must come back bit-identical,
    // with zero fresh simulation
    check_property("cache_round_trip", 6, 0xCAC4E, |rng| {
        let (model, ds) = MODELS[rng.below(3) as usize]; // small models
        let cfg = random_cfg(rng).with_model(model, ds);
        let tiles = [rng.range(4, 12) as usize, rng.range(13, 30) as usize];
        let path = cache_scratch("round_trip");
        let spath = path.to_str().unwrap().to_string();
        let run = || {
            SweepBuilder::new(&cfg)
                .tiles(&tiles)
                .chiplet_counts(&[None])
                .cache_file(&spath)
                .run()
                .unwrap()
        };
        let cold = run();
        let warm = run();
        assert_eq!(warm.stats.epoch_misses, 0, "warm run must only replay");
        assert!(warm.stats.epochs_hydrated > 0);
        // every grid point (evaluated or skipped) was fingerprinted
        assert_eq!(warm.stats.points_known, tiles.len());
        assert_eq!(cold.len(), warm.len());
        for (c, w) in cold.points.iter().zip(&warm.points) {
            assert_eq!(c.tiles_per_chiplet, w.tiles_per_chiplet);
            assert_eq!(
                c.report.total.latency_ns.to_bits(),
                w.report.total.latency_ns.to_bits()
            );
            assert_eq!(c.report.total.energy_pj.to_bits(), w.report.total.energy_pj.to_bits());
            assert_eq!(c.report.total.area_um2.to_bits(), w.report.total.area_um2.to_bits());
            assert_eq!(c.report.engine_tiers, w.report.engine_tiers);
        }
        let _ = std::fs::remove_file(&path);
    });
}

#[test]
fn pruned_searches_find_the_exhaustive_best_on_random_grids() {
    use siam::config::SearchMode;
    use siam::coordinator::{FigureOfMerit, SweepBuilder};
    const FOMS: [FigureOfMerit; 6] = [
        FigureOfMerit::Edap,
        FigureOfMerit::Edp,
        FigureOfMerit::Energy,
        FigureOfMerit::Latency,
        FigureOfMerit::Area,
        FigureOfMerit::InferencesPerJoule,
    ];
    const TILE_POOL: [usize; 10] = [2, 4, 6, 9, 12, 16, 20, 25, 30, 36];
    check_property("pruned_search_argmax", 8, 0x9A2370, |rng| {
        let (model, ds) = MODELS[rng.below(3) as usize]; // small models
        let cfg = random_cfg(rng).with_model(model, ds);
        // a random 3..5-point tile grid from the pool, ascending
        let mut tiles: Vec<usize> = TILE_POOL.to_vec();
        while tiles.len() > rng.range(3, 5) as usize {
            tiles.remove(rng.below(tiles.len() as u64) as usize);
        }
        let fom = FOMS[rng.below(FOMS.len() as u64) as usize];
        let keep = 0.1 + 0.9 * (rng.below(1000) as f64 / 1000.0);
        let exhaustive = SweepBuilder::new(&cfg)
            .tiles(&tiles)
            .chiplet_counts(&[None])
            .figure_of_merit(fom)
            .serial()
            .run()
            .unwrap();
        let Some(want) = exhaustive.best() else {
            return; // nothing fits this grid: both modes must agree on that
        };
        let want_key = (want.tiles_per_chiplet, want.report.total.edap().to_bits());
        for mode in [SearchMode::Pareto, SearchMode::Halving] {
            let got = SweepBuilder::new(&cfg)
                .tiles(&tiles)
                .chiplet_counts(&[None])
                .figure_of_merit(fom)
                .search(mode)
                .halving_keep(keep)
                .run()
                .unwrap();
            let best = got.best().unwrap_or_else(|| {
                panic!("{mode:?} lost the grid: exhaustive found {want_key:?}")
            });
            assert_eq!(
                (best.tiles_per_chiplet, best.report.total.edap().to_bits()),
                want_key,
                "{fom:?} {mode:?} keep={keep}"
            );
        }
    });
}

#[test]
fn interleaved_appends_from_two_handles_never_corrupt_reads() {
    use siam::noc::EpochStore;
    // two handles on the same file, appends interleaved record by
    // record from two threads: every record must survive, exactly once,
    // with nothing torn — appends interleave only at record boundaries
    check_property("two_handle_interleave", 10, 0x2F11E5, |rng| {
        let path = cache_scratch("interleave");
        let a = EpochStore::open(&path).unwrap().0;
        let b = EpochStore::open(&path).unwrap().0;
        let n = rng.range(8, 64);
        std::thread::scope(|s| {
            let ta = s.spawn(|| {
                for i in 0..n {
                    a.record_point((i, 0xA)).unwrap();
                }
            });
            let tb = s.spawn(|| {
                for i in 0..n {
                    b.record_point((i, 0xB)).unwrap();
                }
            });
            ta.join().unwrap();
            tb.join().unwrap();
        });
        drop((a, b));
        let (store, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report.truncated_bytes, 0, "no torn record");
        assert_eq!(report.duplicate_records, 0, "disjoint writers never duplicate");
        assert_eq!(report.points_loaded, 2 * n as usize, "every append survived");
        for i in 0..n {
            assert!(store.known_point((i, 0xA)));
            assert!(store.known_point((i, 0xB)));
        }
        drop(store);
        let _ = std::fs::remove_file(&path);
    });
}

// ---------------------------------------------------------------------
// autoregressive decode serving: continuous-batching invariants
// ---------------------------------------------------------------------

#[test]
fn decode_batching_invariants_hold_for_random_draws() {
    use siam::coordinator::SweepContext;
    // one shared context: every decode run below replays the same
    // cached stage outputs instead of re-simulating the design point
    let base = SiamConfig::paper_default().with_model("gpt2_small", "seq16");
    let ctx = SweepContext::new(&base).unwrap();
    check_property("decode_batching_invariants", 12, 0xDEC0DE, |rng| {
        let tokens = rng.range(2, 8) as usize;
        let cap = rng.range(1, 6) as usize;
        let requests = rng.range(2, 24) as usize;
        let kv_bits = [4, 8, 16][rng.below(3) as usize];
        let mut cfg = base
            .clone()
            .with_decode(tokens, kv_bits, cap)
            .with_serve_open(0.0)
            .with_serve_requests(requests);
        cfg.serve.seed = rng.next_u64();
        let a = siam::serve::evaluate_decode(&cfg, &ctx).unwrap();
        let b = siam::serve::evaluate_decode(&cfg, &ctx).unwrap();
        // same seed => bit-identical serialized reports
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "same-seed decode runs diverged"
        );
        // conservation at drain: every offered request either finished
        // its trajectory or was shed; nothing stays in flight
        assert_eq!(a.requests, requests, "offered count drifted");
        assert_eq!(a.requests, a.completed + a.dropped, "requests leaked");
        let d = a.decode.as_ref().expect("decode fragment");
        // the batch never exceeds its cap, and every completed request
        // contributed exactly max_new_tokens tokens
        assert!(d.occupancy_peak <= cap, "occupancy {} > cap {cap}", d.occupancy_peak);
        assert_eq!(d.total_tokens, (a.completed * tokens) as u64, "token accounting");
        // KV accounting: the peak is at least one request's full
        // trajectory whenever anything completed, and spill never
        // exceeds the peak residency demand
        if a.completed > 0 {
            assert!(d.kv_peak_bytes >= d.kv_bytes_per_token * (16 + tokens - 1));
            assert!(d.kv_spill_bytes_peak <= d.kv_peak_bytes);
        }
    });
}

#[test]
fn decode_closed_concurrency_one_matches_closed_form_for_random_draws() {
    use siam::coordinator::SweepContext;
    // concurrency 1 degenerates to sequential generation: delivered
    // tokens/s must equal the analytic per-token reciprocal to fp
    // accumulation error, for any trajectory length or KV precision
    let base = SiamConfig::paper_default().with_model("gpt2_small", "seq16");
    let ctx = SweepContext::new(&base).unwrap();
    check_property("decode_conc1_closed_form", 8, 0x70C_E115, |rng| {
        let tokens = rng.range(2, 8) as usize;
        let kv_bits = [4, 8, 16][rng.below(3) as usize];
        let requests = rng.range(1, 6) as usize;
        let cfg = base
            .clone()
            .with_decode(tokens, kv_bits, 1)
            .with_serve_closed(1)
            .with_serve_requests(requests);
        let rep = siam::serve::evaluate_decode(&cfg, &ctx).unwrap();
        let d = rep.decode.as_ref().expect("decode fragment");
        let want = 1.0e9 / d.per_token_ns;
        let rel = (d.tokens_per_second - want).abs() / want;
        assert!(rel < 1e-9, "closed-1 tokens/s {} vs closed form {want}: rel {rel}", d.tokens_per_second);
        assert_eq!(d.occupancy_peak, 1, "concurrency 1 batches");
    });
}
