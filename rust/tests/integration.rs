//! Integration tests: the full pipeline (mapping → circuit/NoC/NoP/DRAM
//! → report) across models, modes and configs, asserting the paper's
//! qualitative results end-to-end.

use siam::config::{ChipMode, ChipletStructure, SiamConfig};
use siam::coordinator::{simulate, sweep};
use siam::cost::CostModel;
use siam::gpu_baseline::{T4, V100};

#[test]
fn every_zoo_model_simulates() {
    for name in siam::dnn::zoo_names() {
        let ds = siam::dnn::default_dataset(name);
        let cfg = SiamConfig::paper_default().with_model(name, ds);
        let rep = simulate(&cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(rep.total.energy_pj > 0.0, "{name} energy");
        assert!(rep.total.latency_ns > 0.0, "{name} latency");
        assert!(rep.total.area_um2 > 0.0, "{name} area");
        let min_util = if rep.total_tiles > 20 { 0.3 } else { 0.02 };
        assert!(
            rep.xbar_utilization > min_util && rep.xbar_utilization <= 1.0,
            "{name} utilization {}",
            rep.xbar_utilization
        );
    }
}

#[test]
fn gpu_comparison_shape_holds() {
    // Section 6.5: IMC wins on energy-efficiency by >30x against both
    // GPUs and the V100 < T4 efficiency ordering is preserved.
    let cfg = SiamConfig::paper_default()
        .with_model("resnet50", "imagenet")
        .with_tiles_per_chiplet(36);
    let rep = simulate(&cfg).unwrap();
    let eff = rep.inferences_per_joule();
    let vs_v100 = eff / V100.inferences_per_joule();
    let vs_t4 = eff / T4.inferences_per_joule();
    assert!(vs_v100 > 30.0, "V100 advantage only {vs_v100:.1}x");
    assert!(vs_t4 > 15.0, "T4 advantage only {vs_t4:.1}x");
    assert!(vs_v100 > vs_t4, "V100 must be the weaker baseline");
    // area: IMC die smaller than both GPUs (paper: 273 vs 525 / 815 mm²)
    assert!(rep.total.area_mm2() < T4.area_mm2);
}

#[test]
fn fig13_cost_improvement_shape() {
    // small nets gain ~nothing; big nets gain a lot
    let cost = CostModel::default();
    let improvement = |model: &str, ds: &str| {
        let base = SiamConfig::paper_default().with_model(model, ds);
        let mono = simulate(&base.clone().with_chip_mode(ChipMode::Monolithic)).unwrap();
        let chip = simulate(&base).unwrap();
        cost.improvement_pct(
            mono.silicon_area_mm2,
            chip.num_chiplets,
            chip.silicon_area_mm2 / chip.num_chiplets as f64,
        )
    };
    let small = improvement("resnet110", "cifar10");
    let big = improvement("vgg16", "imagenet");
    assert!(big > 50.0, "VGG-16 improvement {big:.1}%");
    assert!(small < big, "ResNet-110 ({small:.1}%) must gain less than VGG-16 ({big:.1}%)");
}

#[test]
fn sweep_over_grid_is_consistent() {
    let pts = sweep(
        &SiamConfig::paper_default(),
        &[9, 16],
        &[Some(36), None],
    )
    .unwrap();
    assert_eq!(pts.len(), 4);
    for p in &pts {
        // homogeneous architecture contains at least the used chiplets
        assert!(p.report.num_chiplets >= p.report.num_chiplets_required);
        if p.total_chiplets.is_none() {
            assert_eq!(p.report.num_chiplets, p.report.num_chiplets_required);
        }
    }
}

#[test]
fn config_file_round_trip_drives_simulation() {
    let text = SiamConfig::paper_default()
        .with_model("lenet5", "cifar10")
        .to_toml_string()
        .unwrap();
    let dir = std::env::temp_dir().join("siam_test_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.toml");
    std::fs::write(&path, &text).unwrap();
    let cfg = SiamConfig::from_toml_file(&path).unwrap();
    assert_eq!(cfg.dnn.model, "lenet5");
    let rep = simulate(&cfg).unwrap();
    assert_eq!(rep.model, "lenet5");
}

#[test]
fn presets_in_configs_dir_are_valid() {
    for entry in std::fs::read_dir(concat!(env!("CARGO_MANIFEST_DIR"), "/configs")).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) == Some("toml") {
            SiamConfig::from_toml_file(&path)
                .unwrap_or_else(|e| panic!("preset {path:?} invalid: {e}"));
        }
    }
}

/// Serialize → parse → serialize must be a fixed point: the second
/// serialization is byte-identical to the first, so every field —
/// floats included — survives the TOML subset bit-exactly.
fn assert_toml_fixed_point(cfg: &SiamConfig, label: &str) {
    let once = cfg.to_toml_string().unwrap();
    let back = SiamConfig::from_toml_str(&once)
        .unwrap_or_else(|e| panic!("{label}: serialized config does not re-parse: {e}"));
    let twice = back.to_toml_string().unwrap();
    assert_eq!(once, twice, "{label}: TOML round trip is not bit-identical");
}

#[test]
fn every_preset_and_default_round_trips_bit_identically() {
    assert_toml_fixed_point(&SiamConfig::paper_default(), "paper_default");
    let mut seen = 0;
    for entry in std::fs::read_dir(concat!(env!("CARGO_MANIFEST_DIR"), "/configs")).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) == Some("toml") {
            let cfg = SiamConfig::from_toml_file(&path).unwrap();
            assert_toml_fixed_point(&cfg, path.to_str().unwrap());
            seen += 1;
        }
    }
    assert!(seen >= 4, "expected the checked-in presets, found {seen}");
}

#[test]
fn serve_cli_smoke_shape() {
    // the `siam serve --quick` CI smoke, exercised at the library level:
    // paper-default config, capped request count, JSON renders
    let cfg = SiamConfig::paper_default().with_serve_requests(200);
    let rep = siam::serve::serve(&cfg).unwrap();
    assert_eq!(rep.model, "resnet110");
    assert!(rep.completed > 0);
    assert!(rep.throughput_qps > 0.0);
    assert!(rep.p50_ms <= rep.p95_ms && rep.p95_ms <= rep.p99_ms);
    assert!(rep.bottleneck_qps >= rep.throughput_qps * (1.0 - 1e-9));
    let j = rep.to_json().to_string_pretty();
    siam::util::json::parse(&j).expect("serve JSON parses");
}

#[test]
fn chiplet_beats_monolithic_on_cost_not_performance() {
    // chiplet architectures pay interconnect overhead but win fab cost
    let base = SiamConfig::paper_default().with_model("vgg19", "cifar100");
    let mono = simulate(&base.clone().with_chip_mode(ChipMode::Monolithic)).unwrap();
    let chip = simulate(&base).unwrap();
    // energy overhead of the chiplet system is bounded (same compute,
    // plus NoP transfers and idle-window leakage)
    let ratio = chip.total.energy_pj / mono.total.energy_pj;
    assert!((1.0..15.0).contains(&ratio), "energy ratio {ratio}");
    // fab cost must improve
    let cost = CostModel::default();
    let mono_c = cost.normalized_die_cost(mono.silicon_area_mm2);
    let chip_c = cost.chiplet_system_cost(
        chip.num_chiplets,
        chip.silicon_area_mm2 / chip.num_chiplets as f64,
    );
    assert!(chip_c < mono_c);
}

#[test]
fn bigger_batch_serializes() {
    let mut cfg = SiamConfig::paper_default().with_model("lenet5", "cifar10");
    let r1 = simulate(&cfg).unwrap();
    cfg.dnn.batch = 8;
    let r8 = simulate(&cfg).unwrap();
    assert!(r8.total.latency_ns > 4.0 * r1.total.latency_ns);
    assert!(r8.total.energy_pj > 4.0 * r1.total.energy_pj);
}

#[test]
fn sparsity_reduces_crossbars() {
    let dnn = siam::dnn::build_model("vgg16", "imagenet").unwrap();
    let nlayers = dnn.weight_layers().len();
    let mut cfg = SiamConfig::paper_default().with_model("vgg16", "imagenet");
    let dense = simulate(&cfg).unwrap();
    cfg.dnn.sparsity = Some(vec![0.5; nlayers]);
    let sparse = simulate(&cfg).unwrap();
    assert!(sparse.total_tiles < dense.total_tiles);
    assert!(sparse.total.energy_pj < dense.total.energy_pj);
}

#[test]
fn hetero_biglittle_preset_reduces_nop_energy_vs_homogeneous() {
    // the heterogeneity acceptance gate, at the library level: the
    // checked-in big-little preset (class-aware packing + dataflow
    // placement) must strictly cut NoP energy against the homogeneous
    // 36-chiplet system on ResNet-110
    let preset = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/hetero_biglittle.toml");
    let hetero_cfg = SiamConfig::from_toml_file(preset).unwrap();
    assert!(hetero_cfg.has_hetero_classes(), "preset must be genuinely heterogeneous");
    let hetero = simulate(&hetero_cfg).unwrap();
    assert_eq!(hetero.chiplets_per_class.len(), 2);
    assert!(
        hetero.chiplets_per_class.iter().all(|&(_, c)| c > 0),
        "expected a mixed big-little split, got {:?}",
        hetero.chiplets_per_class
    );
    let homog = simulate(&SiamConfig::paper_default().with_total_chiplets(36)).unwrap();
    assert!(
        hetero.nop.energy_pj < homog.nop.energy_pj,
        "big-little NoP energy {} must undercut homogeneous {}",
        hetero.nop.energy_pj,
        homog.nop.energy_pj
    );
    // reports carry the split into JSON
    let j = hetero.to_json().to_string_pretty();
    let parsed = siam::util::json::parse(&j).expect("hetero report JSON parses");
    assert!(parsed.get("classes").is_some(), "JSON must list the class split");
}

#[test]
fn homogeneous_architecture_variants_rank_sanely() {
    // Fig. 12a at 16 t/c: more homogeneous chiplets => more area & EDAP
    let e36 = simulate(&SiamConfig::paper_default().with_total_chiplets(36)).unwrap();
    let e100 = simulate(&SiamConfig::paper_default().with_total_chiplets(100)).unwrap();
    assert!(e100.total.area_um2 > e36.total.area_um2);
    assert!(e100.total.edap() > e36.total.edap());
}

// ---------------------------------------------------------------------------
// DNN frontend: file-based network descriptions (the `configs/models/` zoo)

/// Path of a checked-in network file.
fn model_file(name: &str) -> String {
    format!("{}/configs/models/{name}.toml", env!("CARGO_MANIFEST_DIR"))
}

/// The deterministic fields two reports of the same workload must share
/// bit-for-bit.
fn assert_sim_reports_bit_identical(
    a: &siam::coordinator::SimReport,
    b: &siam::coordinator::SimReport,
) {
    assert_eq!(a.model, b.model);
    assert_eq!(a.params, b.params);
    assert_eq!(a.macs, b.macs);
    assert_eq!(a.num_chiplets, b.num_chiplets);
    assert_eq!(a.total_tiles, b.total_tiles);
    assert_eq!(a.noc_cycles, b.noc_cycles);
    assert_eq!(a.nop_cycles, b.nop_cycles);
    assert_eq!(a.accumulator_adds, b.accumulator_adds);
    for (x, y) in [
        (a.total.area_um2, b.total.area_um2),
        (a.total.energy_pj, b.total.energy_pj),
        (a.total.latency_ns, b.total.latency_ns),
        (a.total.leakage_uw, b.total.leakage_uw),
        (a.circuit.energy_pj, b.circuit.energy_pj),
        (a.noc.energy_pj, b.noc.energy_pj),
        (a.nop.energy_pj, b.nop.energy_pj),
        (a.xbar_utilization, b.xbar_utilization),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
    }
}

#[test]
fn checked_in_model_files_match_builtin_exports() {
    // the zoo files are exactly what `to_model_toml` exports from the
    // builtin builders — the frontend is self-hosting, byte for byte
    for (name, ds) in [
        ("vit_tiny", "imagenet"),
        ("vit_small", "imagenet"),
        ("bert_base", "seq128"),
        ("gpt2_small", "seq128"),
    ]
    {
        let builtin = siam::dnn::build_model(name, ds).unwrap();
        let exported = siam::dnn::to_model_toml(&builtin)
            .unwrap_or_else(|e| panic!("{name} does not export: {e}"));
        let checked_in = std::fs::read_to_string(model_file(name)).unwrap();
        assert_eq!(exported, checked_in, "{name}: checked-in file drifted from the builder");
    }
}

#[test]
fn builtin_and_file_vit_are_bit_identical_end_to_end() {
    // the acceptance gate: the same network, once from the builtin
    // builder and once parsed from its file description, produces
    // bit-identical reports under one configuration
    let file_dnn = siam::dnn::load_model_file(model_file("vit_tiny")).unwrap();
    let builtin = siam::dnn::build_model("vit_tiny", "imagenet").unwrap();
    assert!(file_dnn.same_graph(&builtin), "file graph differs from builtin");

    let b_cfg = SiamConfig::paper_default().with_model("vit_tiny", "imagenet");
    let mut f_cfg = SiamConfig::paper_default();
    f_cfg.dnn.model = format!("file:{}", model_file("vit_tiny"));
    let b_rep = simulate(&b_cfg).unwrap();
    let f_rep = simulate(&f_cfg).unwrap();
    assert_sim_reports_bit_identical(&b_rep, &f_rep);
    // provenance differs, results do not
    assert_eq!(b_rep.model_source, "builtin");
    assert!(f_rep.model_source.starts_with("file:"), "{}", f_rep.model_source);
    assert!(f_rep.model_source.contains('#'), "fingerprint missing");
}

#[test]
fn file_vit_runs_sim_serve_and_sweep_end_to_end() {
    // a ViT defined purely as a `file:` model drives `siam sim`,
    // `siam serve` and a SweepBuilder sweep — with the sweep's
    // serial-vs-parallel rankings bitwise identical
    let mut cfg = SiamConfig::paper_default();
    cfg.dnn.model = format!("file:{}", model_file("vit_tiny"));
    cfg.serve.requests = 64;

    // single-shot
    let rep = simulate(&cfg).unwrap();
    assert_eq!(rep.model, "vit_tiny");
    assert_eq!(rep.dataset, "imagenet");
    assert!(rep.total.energy_pj > 0.0 && rep.total.latency_ns > 0.0);
    let j = rep.to_json().to_string_pretty();
    let parsed = siam::util::json::parse(&j).unwrap();
    assert!(parsed
        .get("model_source")
        .and_then(|v| v.as_str())
        .is_some_and(|s| s.starts_with("file:")));

    // serving
    let srep = siam::serve::serve(&cfg).unwrap();
    assert_eq!(srep.model, "vit_tiny");
    assert!(srep.completed > 0 && srep.throughput_qps > 0.0);
    assert!(srep.model_source.starts_with("file:"));

    // sweep: serial and parallel engines agree bit-for-bit
    let tiles = [9, 16];
    let serial = siam::coordinator::SweepBuilder::new(&cfg)
        .tiles(&tiles)
        .serial()
        .run()
        .unwrap();
    let parallel = siam::coordinator::SweepBuilder::new(&cfg).tiles(&tiles).run().unwrap();
    assert_eq!(serial.len(), 2);
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(s.tiles_per_chiplet, p.tiles_per_chiplet);
        assert_sim_reports_bit_identical(&s.report, &p.report);
    }
    let rank = |r: &siam::coordinator::SweepResult| -> Vec<(usize, u64)> {
        r.ranked()
            .iter()
            .map(|p| (p.tiles_per_chiplet, p.edap().to_bits()))
            .collect()
    };
    assert_eq!(rank(&serial), rank(&parallel));
}

#[test]
fn every_checked_in_model_file_loads_and_maps() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/models");
    let mut seen = 0;
    for entry in std::fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let dnn = siam::dnn::load_model_file(&path)
            .unwrap_or_else(|e| panic!("{path:?}: {e}"));
        assert!(dnn.stats().params > 0);
        let map = siam::mapping::map_dnn(&dnn, &SiamConfig::paper_default())
            .unwrap_or_else(|e| panic!("{path:?} does not map: {e}"));
        assert!(map.total_xbars() > 0);
        seen += 1;
    }
    assert!(seen >= 3, "expected the transformer zoo files, found {seen}");
}

#[test]
fn transformer_serving_mix_with_file_workload() {
    // a `[serve] workloads` mix naming a builtin transformer and a
    // file model validates and serves
    let mut cfg = SiamConfig::paper_default();
    cfg.serve.requests = 48;
    cfg.serve.workloads = vec![
        "vit_tiny:imagenet".into(),
        format!("file:{}", model_file("vit_tiny")),
    ];
    cfg.validate().unwrap();
    for w in cfg.serve.workloads.clone() {
        let (m, d) = siam::dnn::split_workload(&w, &cfg.dnn.dataset);
        let wcfg = cfg.clone().with_model(m, d);
        let rep = siam::serve::serve(&wcfg).unwrap();
        assert_eq!(rep.model, "vit_tiny");
        assert!(rep.completed > 0);
    }
}

#[test]
fn zero_fault_reports_are_bit_identical_to_the_classic_path() {
    // the fault subsystem's do-no-harm pin: with nothing injected and no
    // spares, the [fault] block (any seed) is invisible — single-shot and
    // serving reports stay bit-identical to the classic path, and no
    // fault/failover fragments appear in them
    let base = SiamConfig::paper_default();
    let a = simulate(&base).unwrap();
    assert!(a.fault.is_none(), "clean run must not carry a fault report");
    let mut seeded = base.clone();
    seeded.fault.seed = 0xFEED_FACE; // an unused stream must change nothing
    let b = simulate(&seeded).unwrap();
    assert_sim_reports_bit_identical(&a, &b);

    let mut scfg = base.clone().with_serve_requests(150);
    let sa = siam::serve::serve(&scfg).unwrap();
    assert!(sa.failover.is_none(), "clean serve must not carry a failover report");
    assert!(!sa.to_json().to_string_pretty().contains("\"failover\""));
    scfg.fault.seed = 0xFEED_FACE;
    let sb = siam::serve::serve(&scfg).unwrap();
    assert_eq!(sa.completed, sb.completed);
    assert_eq!(sa.p50_ms.to_bits(), sb.p50_ms.to_bits());
    assert_eq!(sa.p99_ms.to_bits(), sb.p99_ms.to_bits());
    assert_eq!(sa.throughput_qps.to_bits(), sb.throughput_qps.to_bits());
}

#[test]
fn zero_variation_reports_are_bit_identical_to_the_classic_path() {
    // the variation subsystem's do-no-harm pin: with every noise source
    // zero the [variation] block (any seed / sample count) is invisible —
    // single-shot and serving reports stay bit-identical to the classic
    // path and no variation fragment appears in their JSON
    let base = SiamConfig::paper_default();
    let a = simulate(&base).unwrap();
    assert!(a.variation.is_none(), "clean run must not carry a variation report");
    assert!(!a.to_json().to_string_pretty().contains("\"variation\""));
    let mut seeded = base.clone();
    seeded.variation.seed = 0xFEED_FACE; // an unused stream must change nothing
    seeded.variation.mc_samples = 999;
    assert!(seeded.variation.is_none(), "seed/samples alone keep the block inert");
    let b = simulate(&seeded).unwrap();
    assert_sim_reports_bit_identical(&a, &b);

    let mut scfg = base.clone().with_serve_requests(150);
    let sa = siam::serve::serve(&scfg).unwrap();
    assert!(sa.variation.is_none(), "clean serve must not carry a variation report");
    assert!(!sa.to_json().to_string_pretty().contains("\"variation\""));
    scfg.variation.seed = 0xFEED_FACE;
    let sb = siam::serve::serve(&scfg).unwrap();
    assert_eq!(sa.completed, sb.completed);
    assert_eq!(sa.p50_ms.to_bits(), sb.p50_ms.to_bits());
    assert_eq!(sa.p99_ms.to_bits(), sb.p99_ms.to_bits());
    assert_eq!(sa.throughput_qps.to_bits(), sb.throughput_qps.to_bits());
}

#[test]
fn variation_demo_preset_runs_end_to_end() {
    // the checked-in demo drives the full pipeline: the report carries a
    // Monte-Carlo variation fragment whose accuracy proxy is a real
    // probability and whose mitigation accounting is live
    let preset = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/variation_demo.toml");
    let cfg = SiamConfig::from_toml_file(preset).unwrap();
    assert!(!cfg.variation.is_none(), "demo preset must enable variation");
    let rep = simulate(&cfg).unwrap();
    let v = rep.variation.as_ref().expect("demo run attaches a variation report");
    assert!(v.accuracy_proxy_mean > 0.0 && v.accuracy_proxy_mean < 1.0);
    assert!(v.accuracy_proxy_ci95 >= 0.0);
    assert!(v.program_energy_pj > 0.0, "write-verify cycles must charge energy");
    assert_eq!(v.mc_samples, cfg.variation.mc_samples);
    let j = rep.to_json().to_string_pretty();
    let parsed = siam::util::json::parse(&j).unwrap();
    let frag = parsed.get("variation").expect("variation fragment in JSON");
    assert!(frag.get("accuracy_proxy_mean").is_some() && frag.get("meets_floor").is_some());

    // Monte-Carlo results are bit-reproducible per (config, seed) through
    // the full pipeline, and the seed genuinely feeds the draws
    let again = simulate(&cfg).unwrap();
    let w = again.variation.as_ref().unwrap();
    assert_eq!(v.accuracy_proxy_mean.to_bits(), w.accuracy_proxy_mean.to_bits());
    assert_eq!(v.read_energy_delta_pj.to_bits(), w.read_energy_delta_pj.to_bits());
    let mut reseeded = cfg.clone();
    reseeded.variation.seed ^= 0xA5A5;
    let r = simulate(&reseeded).unwrap().variation.unwrap();
    assert_ne!(
        v.accuracy_proxy_mean.to_bits(),
        r.accuracy_proxy_mean.to_bits(),
        "a different seed must change the Monte-Carlo draws"
    );

    // serving on the same preset: drift-refresh maintenance steals
    // service time, so the refreshed pipeline is strictly slower per
    // request than the same point with variation disabled
    let mut scfg = cfg.clone().with_serve_requests(96).with_refresh_interval(60.0);
    let srep = siam::serve::serve(&scfg).unwrap();
    let sv = srep.variation.as_ref().expect("serving attaches a variation report");
    assert!(sv.refresh_duty > 0.0, "a 60 s refresh interval must cost duty");
    assert!(srep.to_json().to_string_pretty().contains("\"variation\""));
    scfg.variation = siam::config::VariationConfig::default();
    let clean = siam::serve::serve(&scfg).unwrap();
    assert!(
        srep.p50_ms > clean.p50_ms,
        "refresh duty must inflate latency: {} vs {}",
        srep.p50_ms,
        clean.p50_ms
    );
}

#[test]
fn spare_chiplets_are_charged_but_idle_until_faults() {
    // spares extend the architecture (area, chiplet count) without
    // touching the workload's mapping or latency while nothing fails
    let base = SiamConfig::paper_default();
    let clean = simulate(&base).unwrap();
    let spared = simulate(&base.clone().with_spare_chiplets(2)).unwrap();
    let f = spared.fault.as_ref().expect("spared run reports fault state");
    assert_eq!(f.spare_chiplets, 2);
    assert!(!f.remapped);
    assert_eq!(spared.num_chiplets, clean.num_chiplets + 2);
    assert_eq!(spared.num_chiplets_required, clean.num_chiplets_required);
    assert!(spared.total.area_um2 > clean.total.area_um2, "spares must be charged in area");
    // the report JSON carries the fault fragment with its stable keys
    let j = spared.to_json().to_string_pretty();
    let parsed = siam::util::json::parse(&j).unwrap();
    let frag = parsed.get("fault").expect("fault fragment in JSON");
    assert!(frag.get("spare_chiplets").is_some() && frag.get("remapped").is_some());
}

#[test]
fn zoo_golden_params_and_crossbars_are_stable() {
    // exact golden pins for every zoo entry: parameter count and the
    // Eq.-1 crossbar total at the paper-default geometry (the figures
    // the docs/MODELS.md reference table quotes). Any builder or
    // mapping drift shows up here first.
    let golden: &[(&str, usize, usize)] = &[
        ("lenet5", 62006, 42),
        ("nin", 966986, 514),
        ("resnet20", 271690, 166),
        ("resnet56", 853642, 502),
        ("resnet110", 1726570, 1006),
        ("resnet50", 25530472, 12504),
        ("vgg16", 138357544, 67576),
        ("vgg19", 39316644, 19224),
        ("densenet40", 1002538, 671),
        ("densenet110", 27022474, 17320),
        ("drivenet", 252208, 145),
        ("vit_tiny", 5717032, 3366),
        ("vit_small", 22049896, 10701),
        ("bert_base", 108891650, 41478),
        // decoder: 12 blocks x (attn 1152 + fc1 1152 + fc2 1152) +
        // tied unembed 6*ceil(50257*8/128) = 18852 crossbars
        ("gpt2_small", 124439808, 60324),
    ];
    assert_eq!(golden.len(), siam::dnn::zoo_names().len(), "golden table covers the zoo");
    for &(name, params, xbars) in golden {
        let dnn = siam::dnn::build_model(name, siam::dnn::default_dataset(name)).unwrap();
        assert_eq!(dnn.stats().params, params, "{name} params drifted");
        let map = siam::mapping::map_dnn(&dnn, &SiamConfig::paper_default()).unwrap();
        assert_eq!(map.total_xbars(), xbars, "{name} mapped crossbars drifted");
    }
}

#[test]
fn decode_block_is_inert_for_existing_paths() {
    // the decode subsystem rides behind `[decode]`: with the block
    // absent, single-shot and classic serving reports carry no decode
    // fragment and the exported config carries no [decode] section —
    // pre-decode artifact consumers see byte-identical shapes
    let cfg = SiamConfig::paper_default();
    assert!(cfg.decode.is_default(), "paper default must leave decode inert");
    assert!(
        !cfg.to_toml_string().contains("[decode]"),
        "inert decode config must not export a [decode] section"
    );
    let sim = simulate(&cfg).unwrap().to_json().to_string_pretty();
    assert!(!sim.contains("\"decode\""), "SimReport grew a decode key");
    let mut scfg = cfg.clone().with_serve_closed(2);
    scfg.serve.requests = 64;
    let srv = siam::serve::serve(&scfg).unwrap().to_json().to_string_pretty();
    assert!(!srv.contains("\"decode\""), "classic ServeReport grew a decode key");
    // and the decode entry point refuses non-decoder workloads instead
    // of silently changing them
    let err = siam::serve::serve_decode(&scfg).unwrap_err().to_string();
    assert!(err.contains("seq<N>"), "unexpected gating error: {err}");
}

#[test]
fn decode_serving_end_to_end_smoke() {
    // full pipeline through the public entry point: gpt2_small prefill +
    // decode epochs, KV accounting and percentiles land in the report
    let mut cfg = SiamConfig::paper_default()
        .with_model("gpt2_small", "seq32")
        .with_decode(4, 8, 2)
        .with_serve_closed(2);
    cfg.serve.requests = 4;
    let rep = siam::serve::serve_decode(&cfg).unwrap();
    assert_eq!(rep.completed, 4);
    let d = rep.decode.as_ref().expect("decode fragment");
    assert_eq!(d.total_tokens, 16);
    assert!(d.tokens_per_second > 0.0 && d.ttft_p50_ms > 0.0 && d.tpot_p50_ms > 0.0);
    // KV geometry: 2 directions x 12 layers x 768 channels x 8 bits
    assert_eq!(d.kv_bytes_per_token, 2 * 12 * 768);
    let j = rep.to_json().to_string_pretty();
    let parsed = siam::util::json::parse(&j).unwrap();
    assert!(parsed.get("decode").is_some(), "decode fragment missing from JSON");
}
