//! Concurrency stress for the persistent epoch cache: several threads
//! share one `EpochStore` handle while sweeping disjoint shards of the
//! design grid. The file must come out of it healthy — it reloads
//! cleanly, every shard's point fingerprint is present exactly once,
//! and the results match the single-threaded no-cache reference bit
//! for bit.

use siam::config::{ChipletStructure, SiamConfig};
use siam::coordinator::{SweepBuilder, SweepPoint};
use siam::noc::EpochStore;
use siam::obs::meta::point_fingerprint;
use std::path::PathBuf;
use std::sync::Arc;

/// The sharded grid: each inner slice is one thread's tile axis.
const SHARDS: [&[usize]; 4] = [&[4], &[9], &[16], &[25]];

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("siam_cache_stress_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{}_{}.cache", name, std::process::id()));
    let _ = std::fs::remove_file(&path);
    path
}

/// The bit pattern of everything a sweep point reports that the
/// rankings depend on.
fn point_bits(p: &SweepPoint) -> (usize, u64, u64, u64, u64) {
    (
        p.report.num_chiplets,
        p.report.total.latency_ns.to_bits(),
        p.report.total.energy_pj.to_bits(),
        p.report.total.area_um2.to_bits(),
        p.report.total.edap().to_bits(),
    )
}

#[test]
fn concurrent_shards_share_one_cache_file_safely() {
    let base = SiamConfig::paper_default();
    let path = scratch("shards");
    let store = Arc::new(EpochStore::open(&path).unwrap().0);

    // one thread per shard, all appending through the same handle
    let shard_points: Vec<Vec<SweepPoint>> = std::thread::scope(|s| {
        let handles: Vec<_> = SHARDS
            .iter()
            .map(|&tiles| {
                let store = store.clone();
                let base = &base;
                s.spawn(move || {
                    SweepBuilder::new(base)
                        .tiles(tiles)
                        .chiplet_counts(&[None])
                        .cache_store(store)
                        .run()
                        .unwrap()
                        .points
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // the single-threaded, cache-free reference over the merged grid
    let all_tiles: Vec<usize> = SHARDS.iter().flat_map(|s| s.iter().copied()).collect();
    let reference = SweepBuilder::new(&base)
        .tiles(&all_tiles)
        .chiplet_counts(&[None])
        .serial()
        .run()
        .unwrap();
    assert_eq!(reference.len(), SHARDS.len());

    // every shard's single point matches its reference point bitwise
    for (shard, reference_point) in shard_points.iter().zip(&reference.points) {
        assert_eq!(shard.len(), 1);
        assert_eq!(shard[0].tiles_per_chiplet, reference_point.tiles_per_chiplet);
        assert_eq!(point_bits(&shard[0]), point_bits(reference_point));
    }

    // the file the threads raced on reloads cleanly: no torn tail, no
    // duplicate records, every shard's fingerprint present exactly once
    drop(store);
    let (store, report) = EpochStore::open(&path).unwrap();
    assert_eq!(report.truncated_bytes, 0, "no torn tail");
    assert!(!report.stale_generation);
    assert_eq!(report.duplicate_records, 0, "each record written exactly once");
    assert_eq!(report.points_loaded, SHARDS.len(), "one fingerprint per shard point");
    assert!(report.epochs_loaded > 0, "the shards' epochs were persisted");
    for &tiles in &SHARDS {
        let pc = base
            .clone()
            .with_tiles_per_chiplet(tiles[0])
            .with_chiplet_structure(ChipletStructure::Custom);
        assert!(
            store.known_point(point_fingerprint(&pc)),
            "tiles={} fingerprint missing",
            tiles[0]
        );
    }

    // a warm merged sweep over the reloaded store replays everything
    // and still ranks exactly like the reference
    let warm = SweepBuilder::new(&base)
        .tiles(&all_tiles)
        .chiplet_counts(&[None])
        .cache_store(Arc::new(store))
        .run()
        .unwrap();
    assert_eq!(warm.stats.epoch_misses, 0, "warm run must only replay");
    assert!(warm.stats.epochs_hydrated > 0);
    assert_eq!(warm.stats.points_known, SHARDS.len());
    assert_eq!(warm.len(), reference.len());
    for (w, r) in warm.points.iter().zip(&reference.points) {
        assert_eq!(point_bits(w), point_bits(r));
    }
    let _ = std::fs::remove_file(&path);
}
