//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The SIAM functional-inference runtime executes AOT-compiled Pallas
//! crossbar kernels through PJRT. This build environment has no XLA
//! shared library, so this stub provides the exact API surface
//! `siam::runtime` compiles against while reporting the backend as
//! unavailable at runtime: [`PjRtClient::cpu`] returns an error, which
//! the runtime callers and the e2e test suite already handle by skipping
//! gracefully. Swapping the real `xla` crate back in requires only a
//! `Cargo.toml` change — no source edits.

use std::fmt;

/// Error type matching the shape of `xla::Error` (message-only here).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` alias mirroring the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT backend is not available in this offline build; \
         link the real xla crate to run functional inference"
            .to_string(),
    )
}

/// Host literal (tensor) handle. Stub: carries no data.
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments (one replica, one partition).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU client. Stub: always reports the backend missing.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    /// Platform name of the backing runtime.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("stub"));
    }
}
