//! Offline in-tree stand-in for the [`anyhow`](https://docs.rs/anyhow)
//! crate, implementing exactly the API subset SIAM uses: [`Error`],
//! [`Result`], the [`Context`] extension trait for `Result` and `Option`,
//! and the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! The build environment vendors no external crates, so this shim keeps
//! the crate's error-handling idiomatic while remaining fully offline.
//! Semantics follow the real crate where they matter:
//!
//! * `Display` shows the outermost context (or the root error when no
//!   context was attached); `Debug` shows the whole cause chain.
//! * [`Error::downcast_ref`] reaches *through* context layers to the
//!   original typed error, so `match`-style recovery keeps working.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamically typed error with a human-readable context stack.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
    /// Context strings, innermost first (pushed as the error propagates).
    context: Vec<String>,
}

/// Plain-message error used by [`anyhow!`] and `Option` contexts.
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Wrap a typed error.
    pub fn new<E: StdError + Send + Sync + 'static>(e: E) -> Error {
        Error {
            inner: Box::new(e),
            context: Vec::new(),
        }
    }

    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error::new(MessageError(msg.to_string()))
    }

    /// Attach a higher-level context message (shown by `Display`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// Downcast to the original typed error, looking through any context
    /// layers added along the way.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.inner.downcast_ref::<E>()
    }

    /// The root error this `Error` was built from.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cause: &(dyn StdError + 'static) = &*self.inner;
        while let Some(src) = cause.source() {
            cause = src;
        }
        cause
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.last() {
            Some(c) => f.write_str(c),
            None => write!(f, "{}", self.inner),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")?;
        let mut causes: Vec<String> = self
            .context
            .iter()
            .rev()
            .skip(1)
            .map(String::clone)
            .collect();
        causes.push(self.inner.to_string());
        // When no context exists, Display already printed the root.
        if self.context.is_empty() {
            causes.pop();
        }
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option` (mirrors `anyhow::Context`).
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Typed(u32);

    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.0)
        }
    }

    impl StdError for Typed {}

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Error::new(Typed(7)).context("outer");
        assert_eq!(e.to_string(), "outer");
    }

    #[test]
    fn downcast_through_context() {
        fn fails() -> Result<()> {
            Err(Typed(3)).context("ctx")
        }
        let e = fails().unwrap_err();
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(3)));
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let e = anyhow!("x = {}", 5);
        assert_eq!(e.to_string(), "x = 5");
        fn bailer() -> Result<()> {
            ensure!(1 + 1 == 2);
            bail!("boom {}", 9)
        }
        assert_eq!(bailer().unwrap_err().to_string(), "boom 9");
    }
}
