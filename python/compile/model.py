"""L2: functional DNN compute graph on the IMC crossbar fabric.

Every conv / fc layer is computed by quantizing activations (uint8) and
weights (int8 two's complement), im2col-ing the activation tensor, and
pushing the GEMM through the L1 Pallas crossbar kernel — exactly the
dataflow of SIAM's chiplet fabric (Section 5 of the paper): crossbar MACs,
digital shift-and-add, (global) accumulation, then pooling / ReLU in the
chiplet's digital units.

This module is build-time only. ``aot.py`` lowers the jitted functions to
HLO text; the Rust runtime (rust/src/runtime) executes the artifacts on the
request path. Python never serves.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from compile.kernels.imc_crossbar import xbar_gemm

# Fixed-point scales: activations live in [0, ACT_CLIP), weights in
# [-W_CLIP, W_CLIP). Static scales keep the AOT graph weight-agnostic.
ACT_CLIP = 4.0
W_CLIP = 1.0
X_LEVELS = 255.0
W_LEVELS = 127.0


def quantize_act(x: jax.Array) -> jax.Array:
    """[0, ACT_CLIP) floats -> integer codes 0..255 (carried as f32)."""
    return jnp.round(jnp.clip(x, 0.0, ACT_CLIP) * (X_LEVELS / ACT_CLIP))


def quantize_w(w: jax.Array) -> jax.Array:
    """[-W_CLIP, W_CLIP) floats -> integer codes -127..127 (as f32)."""
    return jnp.round(jnp.clip(w, -W_CLIP, W_CLIP) * (W_LEVELS / W_CLIP))


def dequant_scale() -> float:
    return (ACT_CLIP / X_LEVELS) * (W_CLIP / W_LEVELS)


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1, padding: int = 1):
    """(N,H,W,C) -> (N*OH*OW, kh*kw*C) patch matrix, row-major over (i,j,c)."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(
                xp[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            )
    patches = jnp.concatenate(cols, axis=-1)  # (N, OH, OW, kh*kw*C)
    return patches.reshape(n * oh * ow, kh * kw * c), (n, oh, ow)


def conv2d_imc(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    padding: int = 1,
    adc_bits: int = 8,
    xbar_rows: int = 128,
) -> jax.Array:
    """Conv layer on the crossbar fabric. x:(N,H,W,C) w:(kh,kw,C,F) b:(F,)."""
    kh, kw, c, f = w.shape
    xq, (n, oh, ow) = im2col(quantize_act(x), kh, kw, stride, padding)
    wq = quantize_w(w).reshape(kh * kw * c, f)
    acc = xbar_gemm(xq, wq, adc_bits=adc_bits, xbar_rows=xbar_rows)
    y = acc * dequant_scale() + b
    return y.reshape(n, oh, ow, f)


def fc_imc(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    adc_bits: int = 8,
    xbar_rows: int = 128,
) -> jax.Array:
    """Fully-connected layer on the crossbar fabric. x:(N,K) w:(K,F)."""
    acc = xbar_gemm(
        quantize_act(x), quantize_w(w), adc_bits=adc_bits, xbar_rows=xbar_rows
    )
    return acc * dequant_scale() + b


def maxpool2(x: jax.Array) -> jax.Array:
    """2x2/2 max pool — the chiplet pooling unit (max mode)."""
    n, h, w, c = x.shape
    return jnp.max(x.reshape(n, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def avgpool2(x: jax.Array) -> jax.Array:
    """2x2/2 average pool — the chiplet pooling unit (avg mode)."""
    n, h, w, c = x.shape
    return jnp.mean(x.reshape(n, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


class CnnParams(NamedTuple):
    """Weights of the small CIFAR CNN used by the functional e2e example."""

    w1: jax.Array  # (3,3,3,C1)
    b1: jax.Array
    w2: jax.Array  # (3,3,C1,C2)
    b2: jax.Array
    w3: jax.Array  # (8*8*C2, 10)
    b3: jax.Array


CNN_C1, CNN_C2 = 8, 16


def cnn_param_shapes(c1: int = CNN_C1, c2: int = CNN_C2):
    return [
        ((3, 3, 3, c1), "w1"),
        ((c1,), "b1"),
        ((3, 3, c1, c2), "w2"),
        ((c2,), "b2"),
        ((8 * 8 * c2, 10), "w3"),
        ((10,), "b3"),
    ]


def init_cnn_params(seed: int = 0, c1: int = CNN_C1, c2: int = CNN_C2) -> CnnParams:
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    he = lambda k, shp, fan: jax.random.normal(k, shp) * (2.0 / fan) ** 0.5
    return CnnParams(
        w1=he(keys[0], (3, 3, 3, c1), 27),
        b1=jnp.zeros((c1,)),
        w2=he(keys[1], (3, 3, c1, c2), 9 * c1),
        b2=jnp.zeros((c2,)),
        w3=he(keys[2], (8 * 8 * c2, 10), 8 * 8 * c2),
        b3=jnp.zeros((10,)),
    )


@functools.partial(jax.jit, static_argnames=("adc_bits", "xbar_rows"))
def cnn_forward(
    x: jax.Array,
    w1, b1, w2, b2, w3, b3,
    *,
    adc_bits: int = 8,
    xbar_rows: int = 128,
):
    """CIFAR-shaped CNN, every MAC through the crossbar fabric.

    x: (N, 32, 32, 3) in [0, 1]. Returns (N, 10) logits.
    """
    kw = dict(adc_bits=adc_bits, xbar_rows=xbar_rows)
    h = relu(conv2d_imc(x, w1, b1, **kw))
    h = maxpool2(h)  # 16x16
    h = relu(conv2d_imc(h, w2, b2, **kw))
    h = maxpool2(h)  # 8x8
    h = h.reshape(h.shape[0], -1)
    return fc_imc(h, w3, b3, **kw)


def cnn_forward_ref(x, w1, b1, w2, b2, w3, b3):
    """Float reference of the same CNN (no crossbar, no quantization)."""

    def conv(x, w, b):
        y = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return y + b

    h = relu(conv(x, w1, b1))
    h = maxpool2(h)
    h = relu(conv(h, w2, b2))
    h = maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    return h @ w3 + b3
