"""L1 Pallas kernel: functional model of a bit-serial IMC crossbar GEMM.

This is the compute hot-spot of SIAM's functional fabric model. One grid
step processes one (bm x bn) output block against one 128-row crossbar
slice, mirroring the hardware decomposition of Section 3 of the paper:

  * weights are bit-sliced across ``w_bits`` crossbar columns (1 bit/cell,
    two's complement: the MSB plane carries weight -2^(w_bits-1));
  * inputs are applied bit-serially over ``x_bits`` cycles (no DAC,
    sequential bit-serial computing, Section 3 "Intra-Chiplet IMC
    Architecture");
  * each crossbar column's analog sum (a 0/1-matmul partial sum, at most
    ``xbar_rows``) is digitized by a flash ADC of ``adc_bits`` resolution;
  * shift-and-add circuits recombine the ADC outputs across input and
    weight bit planes;
  * accumulation *across* crossbars (the K dimension) is digital and exact.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): one crossbar tile is
one VMEM block; the bit-plane matmuls are MXU-shaped (128x128); BlockSpec
expresses the HBM->VMEM schedule that the paper's tile/chiplet hierarchy
expresses with buffers. ``interpret=True`` everywhere — the CPU PJRT plugin
cannot run Mosaic custom-calls; numerics are validated against
``ref.py`` and real-TPU utilization is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def adc_quantize(s: jax.Array, adc_bits: int, xbar_rows: int) -> jax.Array:
    """Flash-ADC transfer function for a column analog sum.

    The ADC has ``2**adc_bits`` levels spanning the full-scale range of the
    column current, i.e. ``xbar_rows`` unit cell currents. When the level
    count covers the range (``2**adc_bits - 1 >= xbar_rows``) read-out is
    lossless; otherwise the sum is uniformly quantized with step
    ``xbar_rows / (2**adc_bits - 1)`` (round-half-even, as both jnp and the
    behavioural RTL use).
    """
    levels = (1 << adc_bits) - 1
    if levels >= xbar_rows:
        return s
    step = xbar_rows / levels
    return jnp.round(s / step) * step


def _xbar_block_kernel(x_ref, w_ref, o_ref, *, x_bits, w_bits, adc_bits, xbar_rows):
    """One (bm, rows) x (rows, bn) crossbar block with bit-serial read-out."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # unsigned integers 0 .. 2**x_bits - 1, as f32
    w = w_ref[...]  # two's complement integers, as f32

    # Two's-complement weight bit planes: u = w mod 2**w_bits, bit b of u
    # contributes +2**b for b < w_bits-1 and -2**(w_bits-1) for the MSB.
    w_u = jnp.mod(w, float(1 << w_bits))

    acc = jnp.zeros_like(o_ref[...])
    for t in range(x_bits):
        x_t = jnp.mod(jnp.floor(x / float(1 << t)), 2.0)
        for b in range(w_bits):
            w_b = jnp.mod(jnp.floor(w_u / float(1 << b)), 2.0)
            s = jnp.dot(x_t, w_b, preferred_element_type=jnp.float32)
            q = adc_quantize(s, adc_bits, xbar_rows)
            sign = -1.0 if b == w_bits - 1 else 1.0
            acc = acc + (sign * float(1 << (t + b))) * q
    o_ref[...] += acc


def _pad_to(a: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = a.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return a
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, rem)
    return jnp.pad(a, pads)


@functools.partial(
    jax.jit,
    static_argnames=("x_bits", "w_bits", "adc_bits", "xbar_rows", "bm", "bn"),
)
def xbar_gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    x_bits: int = 8,
    w_bits: int = 8,
    adc_bits: int = 4,
    xbar_rows: int = 128,
    bm: int = 128,
    bn: int = 128,
) -> jax.Array:
    """Quantized GEMM through the IMC crossbar fabric.

    ``x`` is (M, K) with unsigned integer values, ``w`` is (K, N) with
    signed integer values (both carried as float32). K is split into
    ``xbar_rows``-row crossbars, each with its own ADC; zero-padded rows
    contribute nothing (an unprogrammed cell draws no current).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    bm = min(bm, m)
    bn = min(bn, n)

    xp = _pad_to(_pad_to(x, 0, bm), 1, xbar_rows)
    wp = _pad_to(_pad_to(w, 0, xbar_rows), 1, bn)
    gm, gk = xp.shape[0] // bm, xp.shape[1] // xbar_rows
    gn = wp.shape[1] // bn

    kernel = functools.partial(
        _xbar_block_kernel,
        x_bits=x_bits,
        w_bits=w_bits,
        adc_bits=adc_bits,
        xbar_rows=xbar_rows,
    )
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, xbar_rows), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((xbar_rows, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(xp, wp)
    return out[:m, :n]
