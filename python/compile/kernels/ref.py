"""Pure-jnp oracle for the IMC crossbar kernel.

Two references:

* ``ref_exact``  — ideal integer GEMM (what an infinitely precise ADC, or a
  digital MAC array, would compute).
* ``ref_quantized`` — the same bit-serial / bit-sliced / flash-ADC math as
  the Pallas kernel, written as straight-line jnp over K-slices. The kernel
  must match this bit-for-bit; it must match ``ref_exact`` whenever the ADC
  resolution covers the crossbar row count.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_exact(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def _adc(s: jnp.ndarray, adc_bits: int, xbar_rows: int) -> jnp.ndarray:
    levels = (1 << adc_bits) - 1
    if levels >= xbar_rows:
        return s
    step = xbar_rows / levels
    return jnp.round(s / step) * step


def ref_quantized(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    x_bits: int = 8,
    w_bits: int = 8,
    adc_bits: int = 4,
    xbar_rows: int = 128,
) -> jnp.ndarray:
    """Bit-exact model of the crossbar fabric, independent of Pallas."""
    m, k = x.shape
    _, n = w.shape
    w_u = jnp.mod(w, float(1 << w_bits))
    out = jnp.zeros((m, n), dtype=jnp.float32)
    for k0 in range(0, k, xbar_rows):
        xs = x[:, k0 : k0 + xbar_rows]
        ws = w_u[k0 : k0 + xbar_rows, :]
        for t in range(x_bits):
            x_t = jnp.mod(jnp.floor(xs / float(1 << t)), 2.0)
            for b in range(w_bits):
                w_b = jnp.mod(jnp.floor(ws / float(1 << b)), 2.0)
                s = jnp.dot(x_t, w_b, preferred_element_type=jnp.float32)
                q = _adc(s, adc_bits, xbar_rows)
                sign = -1.0 if b == w_bits - 1 else 1.0
                out = out + (sign * float(1 << (t + b))) * q
    return out
