"""AOT emitter: lower the L2 graphs to HLO *text* artifacts for Rust.

HLO text (NOT ``lowered.compile()`` / proto ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` 0.1.6
crate links) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts``
Emits one ``.hlo.txt`` per executable plus ``manifest.json`` describing
every artifact (name, parameter shapes, output shape, metadata) so the Rust
runtime can validate its inputs before handing them to PJRT.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.imc_crossbar import xbar_gemm

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, F32)


def gemm_artifacts():
    """Crossbar GEMM executables at the tile shapes the coordinator uses."""
    arts = []
    for (m, k, n), adc in [
        ((64, 128, 64), 4),
        ((64, 128, 64), 8),
        ((256, 256, 128), 8),
    ]:
        name = f"xbar_gemm_{m}x{k}x{n}_adc{adc}"

        def fn(x, w, _adc=adc):
            return (xbar_gemm(x, w, adc_bits=_adc, xbar_rows=128),)

        arts.append(
            dict(
                name=name,
                lowered=jax.jit(fn).lower(_spec((m, k)), _spec((k, n))),
                params=[list(s) for s in [(m, k), (k, n)]],
                output=[m, n],
                meta=dict(kind="xbar_gemm", m=m, k=k, n=n, adc_bits=adc,
                          xbar_rows=128),
            )
        )
    return arts


def cnn_artifacts(batch: int = 4):
    """Full functional CNN forward (batch, 32, 32, 3) -> (batch, 10)."""
    arts = []
    shapes = [s for s, _ in model.cnn_param_shapes()]
    for adc in (4, 8):
        name = f"cnn_fwd_b{batch}_adc{adc}"

        def fn(x, w1, b1, w2, b2, w3, b3, _adc=adc):
            return (
                model.cnn_forward(
                    x, w1, b1, w2, b2, w3, b3, adc_bits=_adc, xbar_rows=128
                ),
            )

        specs = [_spec((batch, 32, 32, 3))] + [_spec(s) for s in shapes]
        arts.append(
            dict(
                name=name,
                lowered=jax.jit(fn).lower(*specs),
                params=[[batch, 32, 32, 3]] + [list(s) for s in shapes],
                output=[batch, 10],
                meta=dict(kind="cnn_fwd", batch=batch, adc_bits=adc,
                          act_clip=model.ACT_CLIP, w_clip=model.W_CLIP),
            )
        )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="emit artifacts whose name contains this")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for art in gemm_artifacts() + cnn_artifacts():
        if args.only and args.only not in art["name"]:
            continue
        path = os.path.join(args.out_dir, art["name"] + ".hlo.txt")
        text = to_hlo_text(art["lowered"])
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            dict(
                name=art["name"],
                file=art["name"] + ".hlo.txt",
                params=art["params"],
                output=art["output"],
                meta=art["meta"],
            )
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
