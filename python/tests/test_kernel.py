"""Kernel vs ref allclose — the CORE correctness signal for L1.

Hypothesis sweeps shapes, crossbar geometry, bit widths and ADC resolution;
the Pallas kernel (interpret=True) must agree with the pure-jnp oracle
everywhere, and with the exact GEMM whenever the ADC is lossless.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.imc_crossbar import adc_quantize, xbar_gemm
from compile.kernels.ref import ref_exact, ref_quantized


def _rand(rng, m, k, n, x_bits, w_bits):
    x = rng.integers(0, 1 << x_bits, (m, k)).astype(np.float32)
    w = rng.integers(-(1 << (w_bits - 1)), 1 << (w_bits - 1), (k, n)).astype(
        np.float32
    )
    return jnp.array(x), jnp.array(w)


def _tol(out):
    # quantized outputs are multiples of a non-representable step; allow
    # fp32 reassociation error proportional to magnitude
    return 1e-5 * float(jnp.max(jnp.abs(out)) + 1.0)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 200),
    n=st.integers(1, 40),
    xbar_rows=st.sampled_from([16, 32, 64, 128]),
    adc_bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_quantized_ref(m, k, n, xbar_rows, adc_bits, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k, n, 8, 8)
    out = xbar_gemm(x, w, adc_bits=adc_bits, xbar_rows=xbar_rows)
    ref = ref_quantized(x, w, adc_bits=adc_bits, xbar_rows=xbar_rows)
    np.testing.assert_allclose(out, ref, atol=_tol(ref))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 32),
    k=st.integers(1, 150),
    n=st.integers(1, 32),
    x_bits=st.sampled_from([1, 2, 4, 8]),
    w_bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_lossless_adc_is_exact_gemm(m, k, n, x_bits, w_bits, seed):
    # 8-bit ADC covers <=255 unit currents: lossless for xbar_rows<=128,
    # so the bit-serial fabric must reproduce the exact integer GEMM.
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k, n, x_bits, w_bits)
    out = xbar_gemm(
        x, w, x_bits=x_bits, w_bits=w_bits, adc_bits=8, xbar_rows=128
    )
    ref = ref_exact(x, w)
    np.testing.assert_allclose(out, ref, atol=_tol(ref))


@pytest.mark.parametrize("xbar_rows", [64, 128])
@pytest.mark.parametrize("adc_bits", [3, 4, 5])
def test_quantization_error_shrinks_with_adc_bits(xbar_rows, adc_bits):
    rng = np.random.default_rng(7)
    x, w = _rand(rng, 16, 256, 16, 8, 8)
    ex = ref_exact(x, w)
    scale = float(jnp.max(jnp.abs(ex)))
    err_lo = float(
        jnp.max(jnp.abs(xbar_gemm(x, w, adc_bits=adc_bits, xbar_rows=xbar_rows) - ex))
    )
    err_hi = float(
        jnp.max(
            jnp.abs(xbar_gemm(x, w, adc_bits=adc_bits + 2, xbar_rows=xbar_rows) - ex)
        )
    )
    assert err_hi <= err_lo + 1e-4 * scale


def test_adc_quantize_lossless_identity():
    s = jnp.arange(0.0, 129.0)
    np.testing.assert_array_equal(adc_quantize(s, 8, 128), s)


def test_adc_quantize_step_levels():
    # 2-bit ADC over 12-row crossbar: 3 steps of 4 (round-half-even: 2->0)
    s = jnp.array([0.0, 1.0, 2.0, 3.0, 5.0, 11.0, 12.0])
    q = adc_quantize(s, 2, 12)
    np.testing.assert_allclose(q, [0.0, 0.0, 0.0, 4.0, 4.0, 12.0, 12.0])


def test_zero_input_zero_output():
    x = jnp.zeros((8, 64))
    w = jnp.array(np.random.default_rng(1).integers(-128, 128, (64, 8)), jnp.float32)
    np.testing.assert_array_equal(xbar_gemm(x, w, adc_bits=4), jnp.zeros((8, 8)))


def test_negative_weights_two_complement():
    # single -1 weight, input 1 => output -1 through the MSB-negative plane
    x = jnp.ones((1, 1), jnp.float32)
    w = jnp.full((1, 1), -1.0, jnp.float32)
    out = xbar_gemm(x, w, adc_bits=8, xbar_rows=128)
    np.testing.assert_allclose(out, [[-1.0]], atol=1e-6)


def test_k_padding_is_invisible():
    # K not a multiple of xbar_rows must behave as zero-filled extra rows
    rng = np.random.default_rng(3)
    x, w = _rand(rng, 4, 100, 4, 8, 8)
    out = xbar_gemm(x, w, adc_bits=4, xbar_rows=64)
    xp = jnp.pad(x, ((0, 0), (0, 28)))
    wp = jnp.pad(w, ((0, 28), (0, 0)))
    out_p = xbar_gemm(xp, wp, adc_bits=4, xbar_rows=64)
    np.testing.assert_allclose(out, out_p, atol=_tol(out))
