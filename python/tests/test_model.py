"""L2 model tests: shapes, quantized-vs-float fidelity, im2col correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def test_im2col_matches_conv():
    # im2col + exact GEMM must equal lax.conv for arbitrary tensors
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 5))
    cols, (n, oh, ow) = model.im2col(x, 3, 3, 1, 1)
    got = (cols @ w.reshape(27, 5)).reshape(n, oh, ow, 5)
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_im2col_stride2_shape():
    x = jnp.zeros((1, 16, 16, 4))
    cols, (n, oh, ow) = model.im2col(x, 3, 3, stride=2, padding=1)
    assert (n, oh, ow) == (1, 8, 8)
    assert cols.shape == (64, 36)


def test_quantize_act_range():
    x = jnp.array([-1.0, 0.0, model.ACT_CLIP / 2, model.ACT_CLIP, 100.0])
    q = model.quantize_act(x)
    assert float(q[0]) == 0.0
    assert float(q[-1]) == 255.0
    assert jnp.all((q >= 0) & (q <= 255))
    assert jnp.all(q == jnp.round(q))


def test_quantize_w_range():
    w = jnp.array([-10.0, -1.0, 0.0, 0.5, 1.0, 10.0])
    q = model.quantize_w(w)
    assert jnp.all((q >= -127) & (q <= 127))
    assert jnp.all(q == jnp.round(q))


def test_cnn_forward_shape():
    params = model.init_cnn_params(0)
    x = jax.random.uniform(jax.random.PRNGKey(2), (2, 32, 32, 3))
    out = model.cnn_forward(x, *params, adc_bits=8)
    assert out.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_cnn_quantized_tracks_float_ref():
    # With a lossless ADC the only error is 8-bit weight/act quantization;
    # logits must correlate strongly and mostly agree on argmax.
    params = model.init_cnn_params(3)
    x = jax.random.uniform(jax.random.PRNGKey(4), (8, 32, 32, 3))
    q = np.asarray(model.cnn_forward(x, *params, adc_bits=8))
    f = np.asarray(model.cnn_forward_ref(x, *params))
    corr = np.corrcoef(q.ravel(), f.ravel())[0, 1]
    assert corr > 0.95, f"logit correlation too low: {corr}"
    top1 = (q.argmax(1) == f.argmax(1)).mean()
    assert top1 >= 0.5, f"top-1 agreement too low: {top1}"


def test_cnn_adc4_degrades_gracefully():
    params = model.init_cnn_params(5)
    x = jax.random.uniform(jax.random.PRNGKey(6), (4, 32, 32, 3))
    q8 = np.asarray(model.cnn_forward(x, *params, adc_bits=8))
    q4 = np.asarray(model.cnn_forward(x, *params, adc_bits=4))
    f = np.asarray(model.cnn_forward_ref(x, *params))
    err8 = np.abs(q8 - f).mean()
    err4 = np.abs(q4 - f).mean()
    assert err4 >= err8 - 1e-6  # coarser ADC can't be more accurate
    assert np.all(np.isfinite(q4))


def test_pool_units():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    mp = model.maxpool2(x)
    ap = model.avgpool2(x)
    assert mp.shape == (1, 2, 2, 1)
    np.testing.assert_allclose(mp[0, :, :, 0], [[5.0, 7.0], [13.0, 15.0]])
    np.testing.assert_allclose(ap[0, :, :, 0], [[2.5, 4.5], [10.5, 12.5]])


@pytest.mark.parametrize("batch", [1, 4])
def test_aot_lowering_produces_hlo_text(batch, tmp_path):
    # the AOT path must produce parseable non-trivial HLO text
    from compile import aot

    arts = aot.cnn_artifacts(batch=batch)
    text = aot.to_hlo_text(arts[0]["lowered"])
    assert "HloModule" in text
    assert len(text) > 1000
