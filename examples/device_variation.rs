//! Analog device variation on ResNet-56 / CIFAR-10: the `[variation]`
//! Monte-Carlo model of `rust/configs/variation_demo.toml`, built
//! programmatically.
//!
//! Three views of the same noisy RRAM system:
//!
//! 1. the write-verify ladder — each extra verify cycle shrinks the
//!    effective programming sigma (×0.7) and buys accuracy back at a
//!    strictly positive program-energy cost;
//! 2. drift aging — the accuracy proxy degrades monotonically as the
//!    retention age grows under `G(t) = G0·(t/t0)^(-ν)`;
//! 3. a variation-aware sweep — `SweepBuilder::variation_aware()`
//!    ranks points by EDAP among those meeting the accuracy floor.
//!
//! Run with: `cargo run --release --example device_variation`

use siam::config::SiamConfig;
use siam::coordinator::{simulate, SweepBuilder};
use siam::util::table::{eng, Table};

/// The demo preset's noise sources, on top of `base`.
fn noisy(base: &SiamConfig) -> SiamConfig {
    let mut cfg = base.clone().with_variation_noise(0.05).with_drift(0.02, 1.0e4);
    cfg.variation.stuck_at_on = 0.002;
    cfg.variation.stuck_at_off = 0.005;
    cfg.variation.adc_offset_lsb = 0.25;
    cfg.variation.redundant_cols = 8;
    cfg.variation.mc_samples = 64;
    cfg.variation.accuracy_floor = 0.45;
    cfg.variation.seed = 11;
    cfg
}

fn main() -> anyhow::Result<()> {
    let base = SiamConfig::paper_default().with_model("resnet56", "cifar10");

    // ---- 1. the write-verify mitigation ladder
    let mut t = Table::new(&[
        "verify cycles",
        "sigma_eff",
        "accuracy proxy",
        "ci95",
        "program energy uJ",
        "meets floor",
    ]);
    let mut ladder = Vec::new();
    for cycles in [0u32, 1, 2, 3] {
        let rep = simulate(&noisy(&base).with_write_verify(cycles))?;
        let v = rep.variation.expect("noisy run attaches a variation report");
        t.row(&[
            cycles.to_string(),
            format!("{:.4}", v.sigma_program_effective),
            format!("{:.4}", v.accuracy_proxy_mean),
            format!("{:.4}", v.accuracy_proxy_ci95),
            eng(v.program_energy_pj / 1e6),
            v.meets_floor.to_string(),
        ]);
        ladder.push(v);
    }
    t.print();
    // the acceptance gates: accuracy recovers, and never for free
    for w in ladder.windows(2) {
        assert!(
            w[1].accuracy_proxy_mean > w[0].accuracy_proxy_mean,
            "write-verify must recover accuracy"
        );
        assert!(
            w[1].program_energy_pj > w[0].program_energy_pj,
            "write-verify must charge program energy"
        );
    }
    assert_eq!(ladder[0].program_energy_pj, 0.0, "zero cycles cost nothing");

    // ---- 2. drift aging
    println!("\nretention aging (drift nu = 0.02):");
    let mut last = f64::INFINITY;
    for age_s in [1.0e2, 1.0e4, 1.0e6] {
        let rep = simulate(&noisy(&base).with_write_verify(2).with_drift(0.02, age_s))?;
        let v = rep.variation.unwrap();
        println!(
            "  t = {:>9} s: accuracy proxy {:.4}, read-energy factor {:.4}",
            age_s, v.accuracy_proxy_mean, v.drift_energy_factor
        );
        assert!(v.accuracy_proxy_mean < last, "aging must degrade the proxy");
        last = v.accuracy_proxy_mean;
    }

    // ---- 3. accuracy-floor-constrained design-space exploration
    let res = SweepBuilder::new(&noisy(&base).with_write_verify(2))
        .tiles(&[9, 16, 25])
        .variation_aware()
        .run()?;
    let best = res.best().expect("the noisy sweep keeps its points");
    let bv = best.report.variation.as_ref().unwrap();
    println!(
        "\nvariation-aware sweep: best = {} tiles/chiplet, {} chiplets \
         (accuracy {:.4} >= floor {}, EDAP {:.3e})",
        best.tiles_per_chiplet,
        best.report.num_chiplets,
        bv.accuracy_proxy_mean,
        bv.accuracy_floor,
        best.report.total.edap()
    );
    assert!(bv.meets_floor, "the winning point must clear the accuracy floor");
    println!("acceptance gates passed: recovery at positive cost, monotone aging, floor respected");
    Ok(())
}
