//! Design-space exploration (the paper's Figs. 11/12 axes): sweep tiles
//! per chiplet × chiplet count for a DNN, print the EDAP landscape and
//! the optimal point.
//!
//! Run with: `cargo run --release --example design_space_exploration [model] [dataset]`

use siam::config::SiamConfig;
use siam::coordinator::{dse, sweep};
use siam::util::table::{eng, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("resnet110");
    let dataset = args.get(1).map(String::as_str).unwrap_or("cifar10");

    let base = SiamConfig::paper_default().with_model(model, dataset);
    let tiles = [4, 9, 16, 25, 36];
    let counts = [Some(16), Some(36), Some(64), Some(100), None];

    println!("== DSE for {model}/{dataset}: tiles/chiplet × chiplet count ==\n");
    let pts = sweep(&base, &tiles, &counts)?;

    let mut t = Table::new(&[
        "tiles/chiplet",
        "chiplets",
        "used",
        "util %",
        "area mm2",
        "energy uJ",
        "latency ms",
        "EDAP pJ·ns·mm2",
    ]);
    for p in &pts {
        t.row(&[
            p.tiles_per_chiplet.to_string(),
            p.total_chiplets
                .map(|c| c.to_string())
                .unwrap_or_else(|| "custom".into()),
            p.report.num_chiplets_required.to_string(),
            format!("{:.1}", 100.0 * p.report.xbar_utilization),
            eng(p.report.total.area_mm2()),
            eng(p.report.total.energy_uj()),
            eng(p.report.total.latency_ms()),
            format!("{:.3e}", p.edap()),
        ]);
    }
    t.print();

    if let Some(best) = dse::best_by_edap(&pts) {
        println!(
            "\nEDAP-optimal design: {} tiles/chiplet, {} chiplets ({}) -> {:.3e}",
            best.tiles_per_chiplet,
            best.report.num_chiplets,
            best.total_chiplets
                .map(|_| "homogeneous")
                .unwrap_or("custom"),
            best.edap()
        );
    }
    Ok(())
}
