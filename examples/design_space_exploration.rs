//! Design-space exploration (the paper's Figs. 11/12 axes): sweep tiles
//! per chiplet × chiplet count for a DNN with the parallel memoizing
//! sweep engine, print the EDAP landscape, the ranking, and the
//! serial-vs-parallel wall-clock.
//!
//! Run with: `cargo run --release --example design_space_exploration [model] [dataset]`

use siam::config::SiamConfig;
use siam::coordinator::{FigureOfMerit, SweepBuilder};
use siam::util::table::{eng, Table};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("resnet110");
    let dataset = args.get(1).map(String::as_str).unwrap_or("cifar10");

    let base = SiamConfig::paper_default().with_model(model, dataset);
    let tiles = [4, 9, 16, 25, 36];
    let counts = [Some(16), Some(36), Some(64), Some(100), None];

    println!("== DSE for {model}/{dataset}: tiles/chiplet × chiplet count ==\n");
    let t0 = Instant::now();
    let result = SweepBuilder::new(&base)
        .tiles(&tiles)
        .chiplet_counts(&counts)
        .figure_of_merit(FigureOfMerit::Edap)
        .run()?;
    let parallel_s = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&[
        "rank",
        "tiles/chiplet",
        "chiplets",
        "used",
        "util %",
        "area mm2",
        "energy uJ",
        "latency ms",
        "EDAP pJ·ns·mm2",
    ]);
    for (rank, p) in result.ranked().iter().enumerate() {
        t.row(&[
            (rank + 1).to_string(),
            p.tiles_per_chiplet.to_string(),
            p.total_chiplets
                .map(|c| c.to_string())
                .unwrap_or_else(|| "custom".into()),
            p.report.num_chiplets_required.to_string(),
            format!("{:.1}", 100.0 * p.report.xbar_utilization),
            eng(p.report.total.area_mm2()),
            eng(p.report.total.energy_uj()),
            eng(p.report.total.latency_ms()),
            format!("{:.3e}", p.edap()),
        ]);
    }
    t.print();

    if let Some(best) = result.best() {
        println!(
            "\nEDAP-optimal design: {} tiles/chiplet, {} chiplets ({}) -> {:.3e}",
            best.tiles_per_chiplet,
            best.report.num_chiplets,
            best.total_chiplets
                .map(|_| "homogeneous")
                .unwrap_or("custom"),
            best.edap()
        );
    }

    // serial reference: same grid on one worker, fresh caches
    let t0 = Instant::now();
    let serial = SweepBuilder::new(&base)
        .tiles(&tiles)
        .chiplet_counts(&counts)
        .serial()
        .run()?;
    let serial_s = t0.elapsed().as_secs_f64();
    assert_eq!(serial.len(), result.len(), "engines must agree");
    println!(
        "\nsweep wall-clock: serial {serial_s:.2}s, parallel {parallel_s:.2}s \
         ({:.1}x speedup on {} points)",
        serial_s / parallel_s.max(1e-9),
        result.len(),
    );
    Ok(())
}
