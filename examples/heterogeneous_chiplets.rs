//! Heterogeneous big-little chiplet classes vs single-kind systems on
//! ResNet-110 / CIFAR-10.
//!
//! Builds the big-little system of `rust/configs/hetero_biglittle.toml`
//! programmatically (a "big" RRAM class — the paper's Table-2 chiplet —
//! plus a "little" SRAM class with quarter-size crossbars, 3-bit ADCs
//! and a leaner GRS driver), then compares latency, NoP energy and area
//! against the homogeneous 36-chiplet and custom single-kind systems,
//! under both placement policies.
//!
//! The acceptance gate of the heterogeneity work is asserted here: the
//! big-little system with `placement = "dataflow"` must strictly reduce
//! NoP energy versus the homogeneous architecture.
//!
//! Run with: `cargo run --release --example heterogeneous_chiplets`

use siam::config::{ChipletClassConfig, MemCell, PlacementPolicy, SiamConfig};
use siam::coordinator::simulate;
use siam::util::table::{eng, Table};

/// The big-little class pair of `configs/hetero_biglittle.toml`: the
/// paper's Table-2 chiplet plus a two-chiplet "little" budget of
/// quarter-size SRAM crossbars with 3-bit ADCs and a leaner GRS driver.
fn big_little(base: &SiamConfig) -> Vec<ChipletClassConfig> {
    let big = ChipletClassConfig::from_base(base, "big");
    let mut little = ChipletClassConfig::from_base(base, "little");
    little.count = Some(2);
    little.cell = MemCell::Sram;
    little.xbar_rows = 64;
    little.xbar_cols = 64;
    little.adc_bits = 3;
    little.nop_ebit_pj = 0.3;
    little.nop_txrx_area_um2 = 3000.0;
    vec![big, little]
}

fn main() -> anyhow::Result<()> {
    let base = SiamConfig::paper_default(); // resnet110 / cifar10

    let homogeneous = base.clone().with_total_chiplets(36);
    let custom = base.clone();
    let hetero_rowmajor = base
        .clone()
        .with_chiplet_classes(big_little(&base))
        .with_placement(PlacementPolicy::RowMajor);
    let hetero_dataflow = base
        .clone()
        .with_chiplet_classes(big_little(&base))
        .with_placement(PlacementPolicy::Dataflow);

    let mut t = Table::new(&[
        "system",
        "chiplets",
        "latency ms",
        "NoP energy uJ",
        "total energy uJ",
        "area mm2",
        "EDAP",
    ]);
    let mut nop_energy = Vec::new();
    for (name, cfg) in [
        ("homogeneous-36", &homogeneous),
        ("custom", &custom),
        ("big-little rowmajor", &hetero_rowmajor),
        ("big-little dataflow", &hetero_dataflow),
    ] {
        let rep = simulate(cfg)?;
        let split = if rep.chiplets_per_class.is_empty() {
            rep.num_chiplets.to_string()
        } else {
            rep.chiplets_per_class
                .iter()
                .map(|(n, c)| format!("{c} {n}"))
                .collect::<Vec<_>>()
                .join(" + ")
        };
        t.row(&[
            name.to_string(),
            split,
            eng(rep.total.latency_ms()),
            eng(rep.nop.energy_pj / 1e6),
            eng(rep.total.energy_uj()),
            eng(rep.total.area_mm2()),
            format!("{:.3e}", rep.total.edap()),
        ]);
        nop_energy.push((name, rep.nop.energy_pj, rep));
    }
    t.print();

    let homog_nop = nop_energy[0].1;
    let dataflow = &nop_energy[3];
    println!(
        "\nbig-little dataflow NoP energy: {} of homogeneous-36 ({} uJ vs {} uJ)",
        eng(dataflow.1 / homog_nop),
        eng(dataflow.1 / 1e6),
        eng(homog_nop / 1e6),
    );
    // ---- the heterogeneity acceptance gate
    assert!(
        dataflow.1 < homog_nop,
        "big-little + dataflow must strictly reduce NoP energy vs homogeneous: {} vs {homog_nop}",
        dataflow.1
    );
    println!(
        "dataflow vs rowmajor NoP energy ratio: {:.4} (placement optimizes packet-hops; \
         driver energy is class-weighted, so this is informational)",
        dataflow.1 / nop_energy[2].1
    );
    // the class split must be genuinely mixed (both classes in use)
    let split = &dataflow.2.chiplets_per_class;
    assert!(
        split.iter().all(|&(_, c)| c > 0),
        "expected a mixed big-little split, got {split:?}"
    );
    println!("acceptance gates passed: NoP energy strictly below homogeneous, mixed class split");
    Ok(())
}
