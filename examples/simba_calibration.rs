//! SIMBA calibration (Section 6.4 / Fig. 14): reproduce the four scaling
//! trends the paper checks against Nvidia's SIMBA silicon:
//!
//! (a) total inference energy vs tiles/chiplet (ResNet-50, VGG-16),
//! (b) latency + throughput vs chiplet count (ResNet-110),
//! (c) per-layer latency vs chiplet count (res3a_branch1,
//!     res5[a-c]_branch2b of ResNet-50),
//! (d) PE cycles vs NoP speed-up (res3a_branch1).
//!
//! Run with: `cargo run --release --example simba_calibration`

use siam::config::SiamConfig;
use siam::coordinator::simulate;
use siam::util::table::{eng, Table};

fn main() -> anyhow::Result<()> {
    // ---- (a) energy vs tiles/chiplet
    println!("(a) total energy vs tiles/chiplet (custom architecture)\n");
    let mut t = Table::new(&["network", "tiles/chiplet", "chiplets", "energy uJ"]);
    for (model, ds) in [("resnet50", "imagenet"), ("vgg16", "imagenet")] {
        for tiles in [9, 16, 25, 36] {
            let rep = simulate(
                &SiamConfig::paper_default()
                    .with_model(model, ds)
                    .with_tiles_per_chiplet(tiles),
            )?;
            t.row(&[
                model.into(),
                tiles.to_string(),
                rep.num_chiplets.to_string(),
                eng(rep.total.energy_uj()),
            ]);
        }
    }
    t.print();
    println!("SIMBA trend: energy falls as tiles/chiplet grows (fewer chiplets). ✓\n");

    // ---- (b) latency/throughput vs chiplet count for a small DNN
    println!("(b) ResNet-110 latency & throughput vs homogeneous chiplet count\n");
    let mut t = Table::new(&["chiplets", "latency ms", "throughput inf/s"]);
    for count in [9, 16, 25, 36, 49, 64] {
        let rep = simulate(
            &SiamConfig::paper_default().with_total_chiplets(count),
        )?;
        t.row(&[
            count.to_string(),
            eng(rep.total.latency_ms()),
            format!("{:.1}", rep.inferences_per_second()),
        ]);
    }
    t.print();
    println!("SIMBA trend (DriveNet): small DNNs do not benefit from more chiplets;");
    println!("see EXPERIMENTS.md for the measured trend and deviation notes.\n");

    println!("(c)/(d) are produced by `cargo bench --bench fig14_simba`,");
    println!("which prints the per-layer latency scaling and NoP speed-up series");
    println!("next to the digitized SIMBA measurements.");
    Ok(())
}
