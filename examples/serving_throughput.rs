//! Serving throughput: stream inference requests through the
//! layer-pipelined chiplet system and watch throughput, tail latency
//! and energy-per-inference respond to load.
//!
//! Weight-stationary IMC keeps every layer's weights resident on its
//! chiplet partition, so consecutive requests pipeline across layer
//! stages — single-shot latency says nothing about the throughput this
//! unlocks. This example prints:
//!
//! * a closed-loop concurrency ladder (1 → 32 clients): throughput
//!   climbing from the sequential rate toward the bottleneck-stage
//!   ceiling as the pipeline fills, and
//! * an open-loop (Poisson) load sweep: delivered throughput tracking
//!   offered load below saturation, then plateauing at the ceiling
//!   while back-pressure sheds the excess.
//!
//! Run with: `cargo run --release --example serving_throughput`
//! (optional args: `<model> <dataset>`, default resnet110 cifar10)

use siam::config::SiamConfig;
use siam::coordinator::SweepContext;
use siam::serve;
use siam::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("resnet110");
    let dataset = args.get(1).map(String::as_str).unwrap_or("cifar10");
    let base = SiamConfig::paper_default()
        .with_model(model, dataset)
        .with_serve_requests(1000);

    println!("== Inference serving: {model} / {dataset} ==\n");
    // one shared context: every run below replays the same cached
    // stage outputs instead of re-simulating the design point
    let ctx = SweepContext::new(&base)?;
    let probe = serve::evaluate(&base.clone().with_serve_closed(1), &ctx)?;
    println!(
        "{} pipeline stages on {} chiplets; bottleneck stage {} ({:.3} ms) caps throughput at {:.1} inf/s\n",
        probe.num_stages,
        probe.num_chiplets,
        probe.bottleneck_stage,
        probe.bottleneck_service_ns / 1e6,
        probe.bottleneck_qps
    );

    println!("-- closed loop: concurrency ladder --");
    let mut t = Table::new(&[
        "clients",
        "inf/s",
        "of ceiling %",
        "p50 ms",
        "p99 ms",
        "mean util %",
        "uJ/inf",
    ]);
    for c in [1usize, 2, 4, 8, 16, 32] {
        let rep = serve::evaluate(&base.clone().with_serve_closed(c), &ctx)?;
        t.row(&[
            c.to_string(),
            format!("{:.1}", rep.throughput_qps),
            format!("{:.1}", 100.0 * rep.throughput_qps / rep.bottleneck_qps),
            format!("{:.3}", rep.p50_ms),
            format!("{:.3}", rep.p99_ms),
            format!("{:.1}", 100.0 * rep.mean_utilization),
            format!("{:.2}", rep.energy_per_inference_pj / 1e6),
        ]);
    }
    t.print();

    println!("\n-- open loop: Poisson offered-load sweep --");
    let mut t = Table::new(&[
        "offered/cap",
        "offered inf/s",
        "delivered inf/s",
        "p99 ms",
        "shed %",
    ]);
    for f in [0.25, 0.5, 0.75, 1.0, 1.5, 2.0] {
        let rep = serve::evaluate(&base.clone().with_serve_open(f * probe.bottleneck_qps), &ctx)?;
        t.row(&[
            format!("{f:.2}x"),
            format!("{:.1}", rep.offered_qps),
            format!("{:.1}", rep.throughput_qps),
            format!("{:.3}", rep.p99_ms),
            format!("{:.1}", 100.0 * rep.drop_rate()),
        ]);
    }
    t.print();

    println!("\nfull report of the 1.0x point:\n");
    let rep = serve::evaluate(&base.with_serve_open(probe.bottleneck_qps), &ctx)?;
    println!("{}", rep.summary());
    Ok(())
}
