//! Custom-network frontend walkthrough: author a small hybrid
//! CNN/transformer in the network-file format, run it end to end
//! (single-shot simulation, serving, and a design-space sweep), and
//! show that the checked-in ViT zoo file is bit-identical to its
//! builtin builder.
//!
//! Run with: `cargo run --release --example custom_network`
//! Authoring guide: `docs/MODELS.md`.

use siam::config::SiamConfig;
use siam::coordinator::{simulate, SweepBuilder};
use siam::dnn::{build_model, load_model_file, parse_model_str};

/// A 16-token hybrid network: convolutional patch stem, one pre-norm
/// attention block, global pool, classifier — the worked example from
/// docs/MODELS.md.
const NETWORK: &str = r#"
[model]
name = "hybrid_demo"
dataset = "cifar10"
input = [32, 32, 3]

[[layer]]
type = "conv"           # 8x8/8 patch stem -> 4x4x64 (16 tokens)
name = "patch"
k = 8
stride = 8
out_channels = 64

[[layer]]
type = "layernorm"

[[layer]]
type = "attention"
heads = 4

[[layer]]
type = "residual"
from = "patch"

[[layer]]
type = "conv"           # per-token MLP expansion
name = "mlp_up"
k = 1
out_channels = 256

[[layer]]
type = "gelu"

[[layer]]
type = "conv"
name = "mlp_down"
k = 1
out_channels = 64

[[layer]]
type = "gap"

[[layer]]
type = "fc"
out_features = 10
"#;

fn main() -> anyhow::Result<()> {
    // ---- author + load the file model
    let dir = std::env::temp_dir().join("siam_custom_network_example");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("hybrid_demo.toml");
    std::fs::write(&path, NETWORK)?;

    let dnn = parse_model_str(NETWORK).map_err(|e| anyhow::anyhow!("{e}"))?;
    let s = dnn.stats();
    println!(
        "== {}: {} layers, {:.2}K params, {:.2}M MACs ({:.1}% digital) ==\n",
        dnn.name,
        s.total_layers,
        s.params as f64 / 1e3,
        s.macs as f64 / 1e6,
        100.0 * s.digital_macs as f64 / s.macs as f64,
    );

    // ---- single-shot simulation through `model = "file:..."`
    let mut cfg = SiamConfig::paper_default();
    cfg.dnn.model = format!("file:{}", path.display());
    cfg.serve.requests = 256;
    cfg.validate()?;
    let rep = simulate(&cfg)?;
    println!("{}\n", rep.summary());
    println!("model source: {}\n", rep.model_source);

    // ---- serving under load
    let srep = siam::serve::serve(&cfg)?;
    println!("{}\n", srep.summary());

    // ---- a small sweep, serial vs parallel rankings cross-checked
    let tiles = [4, 9, 16];
    let serial = SweepBuilder::new(&cfg).tiles(&tiles).serial().run()?;
    let parallel = SweepBuilder::new(&cfg).tiles(&tiles).run()?;
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(
            a.report.total.edap().to_bits(),
            b.report.total.edap().to_bits(),
            "serial and parallel sweeps must agree bit-for-bit"
        );
    }
    println!("sweep over tiles/chiplet {tiles:?} (serial == parallel, bitwise):");
    for p in &serial.points {
        println!(
            "  {:>2} tiles/chiplet: {} chiplets, EDAP {:.3e}",
            p.tiles_per_chiplet,
            p.report.num_chiplets,
            p.report.total.edap()
        );
    }

    // ---- self-hosting: the checked-in ViT file == the builtin builder
    // (CARGO_MANIFEST_DIR is the rust/ package root)
    let vit =
        load_model_file(concat!(env!("CARGO_MANIFEST_DIR"), "/configs/models/vit_tiny.toml"))?;
    let builtin = build_model("vit_tiny", "imagenet")?;
    assert!(
        vit.same_graph(&builtin),
        "checked-in vit_tiny.toml must match the builtin builder"
    );
    println!(
        "\nself-hosting check: configs/models/vit_tiny.toml == builtin vit_tiny \
         ({} layers, {:.2}M params)",
        vit.layers.len(),
        vit.stats().params as f64 / 1e6
    );
    Ok(())
}
