//! End-to-end driver: the full three-layer stack on a real small
//! workload.
//!
//! 1. Loads the AOT-compiled Pallas IMC-crossbar executables
//!    (`artifacts/*.hlo.txt`, built once by `make artifacts`) on the
//!    PJRT CPU client — Python is not involved at runtime.
//! 2. Validates the fabric numerically: the lossless (8-bit-ADC)
//!    crossbar GEMM must match an exact integer GEMM computed in Rust.
//! 3. Runs batched CNN inference through the crossbar fabric at 8-bit
//!    and 4-bit ADC resolution and reports the quantization impact.
//! 4. Runs the SIAM performance estimation for the same fabric
//!    configuration and reports the headline metrics, proving the
//!    functional and analytical paths compose.
//!
//! Run with: `make artifacts && cargo run --release --example functional_inference`

use siam::config::SiamConfig;
use siam::coordinator::simulate;
use siam::runtime::{functional, Runtime};
use siam::util::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    println!("== L3 runtime up: PJRT platform = {} ==", rt.platform());
    println!(
        "   manifest: {} artifacts: {:?}\n",
        rt.manifest.len(),
        rt.manifest.iter().map(|a| a.name.as_str()).collect::<Vec<_>>()
    );

    // ---- (2) numerical validation: crossbar GEMM vs exact integer GEMM
    let exe = rt.load("xbar_gemm_64x128x64_adc8")?;
    let (m, k, n) = (64, 128, 64);
    let mut rng = Rng::new(7);
    let (x, w) = functional::synth_gemm_inputs(&mut rng, m, k, n);
    let got = exe.run_f32(&[x.clone(), w.clone()])?;
    let want = functional::ref_gemm(&x, &w, m, k, n);
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("crossbar GEMM vs exact integer GEMM: max |err| = {max_err}");
    anyhow::ensure!(
        max_err < 1.0,
        "lossless crossbar fabric must reproduce the exact GEMM"
    );

    // ---- (3) functional CNN inference at two ADC resolutions
    let r8 = functional::run_cnn(&rt, 8, 42)?;
    let r4 = functional::run_cnn(&rt, 4, 42)?;
    println!(
        "\nfunctional CNN, batch {} (PJRT exec: {:.3}s @8b ADC, {:.3}s @4b ADC)",
        r8.batch, r8.exec_seconds, r4.exec_seconds
    );
    let mut dev = 0.0f32;
    for (a, b) in r8.logits.iter().zip(&r4.logits) {
        dev = dev.max((a - b).abs());
    }
    let agree = r8
        .argmax()
        .iter()
        .zip(r4.argmax())
        .filter(|(a, b)| **a == *b)
        .count();
    println!(
        "  ADC 8b vs 4b: max logit deviation {dev:.3}, top-1 agreement {agree}/{}",
        r8.batch
    );
    println!("  (the 4-bit flash ADC of the paper's default config trades accuracy for\n   the area/energy Fig. 10 reports — this run quantifies that trade)");

    // ---- (4) performance estimation of the same fabric
    println!("\n== SIAM performance estimation for the same IMC fabric ==");
    for (model, ds) in [("resnet110", "cifar10"), ("resnet50", "imagenet")] {
        let rep = simulate(&SiamConfig::paper_default().with_model(model, ds))?;
        println!("{}\n", rep.summary());
    }

    println!("end-to-end OK: AOT kernels + PJRT runtime + performance engines compose.");
    Ok(())
}
