//! Monolithic vs chiplet-based IMC (Sections 6.3 / Fig. 1a / Fig. 13):
//! for each DNN, compare die area, fabrication cost and inference
//! metrics between one big IMC chip and the custom chiplet architecture.
//!
//! Run with: `cargo run --release --example monolithic_vs_chiplet`

use siam::config::{ChipMode, SiamConfig};
use siam::coordinator::simulate;
use siam::cost::CostModel;
use siam::util::table::{eng, Table};

fn main() -> anyhow::Result<()> {
    let nets = [
        ("lenet5", "cifar10"),
        ("resnet110", "cifar10"),
        ("vgg19", "cifar100"),
        ("resnet50", "imagenet"),
        ("densenet110", "cifar10"),
        ("vgg16", "imagenet"),
    ];
    let cost = CostModel::default();

    let mut t = Table::new(&[
        "network",
        "mono mm2",
        "mono cost",
        "chiplets",
        "chiplet mm2",
        "chiplet cost",
        "cost improv %",
        "energy ratio",
    ]);
    for (model, ds) in nets {
        let base = SiamConfig::paper_default().with_model(model, ds);
        let mono = simulate(&base.clone().with_chip_mode(ChipMode::Monolithic))?;
        let chip = simulate(&base)?;

        // yielded silicon only (the passive interposer is not a die)
        let mono_area = mono.silicon_area_mm2;
        let n = chip.num_chiplets;
        let chiplet_area = chip.silicon_area_mm2 / n as f64;
        let mono_cost = cost.normalized_die_cost(mono_area);
        let chip_cost = cost.chiplet_system_cost(n, chiplet_area);
        let improv = 100.0 * (mono_cost - chip_cost) / mono_cost;

        t.row(&[
            model.to_string(),
            eng(mono_area),
            format!("{mono_cost:.3}"),
            n.to_string(),
            eng(chiplet_area),
            format!("{chip_cost:.3}"),
            format!("{improv:.1}"),
            format!("{:.2}", chip.total.energy_pj / mono.total.energy_pj),
        ]);
    }
    t.print();
    println!(
        "\n(cost normalized to a {} mm² reference die; D0 = {}/mm² — Appendix A)",
        cost.reference_area_mm2, cost.defect_density_per_mm2
    );
    Ok(())
}
