//! Quickstart: simulate the paper's default architecture (Section 6.1)
//! for ResNet-110 on CIFAR-10 and print every headline metric.
//!
//! Run with: `cargo run --release --example quickstart`

use siam::config::SiamConfig;
use siam::coordinator::simulate;

fn main() -> anyhow::Result<()> {
    // The paper's Section-6.1 defaults: RRAM, 1 bit/cell, 128×128
    // crossbars, 4-bit flash ADC (8:1 mux), 16 tiles/chiplet, 32 nm,
    // 1 GHz, mesh NoC, GRS NoP at 0.54 pJ/bit, custom chiplet count.
    let cfg = SiamConfig::paper_default();
    println!("== SIAM quickstart: {} / {} ==\n", cfg.dnn.model, cfg.dnn.dataset);

    let report = simulate(&cfg)?;
    println!("{}\n", report.summary());

    println!("component breakdown (Fig. 10 style):");
    let b = report.component_breakdown();
    for (metric, select) in [
        ("area", (|m: &siam::Metrics| m.area_um2) as fn(&siam::Metrics) -> f64),
        ("energy", |m| m.energy_pj),
        ("latency", |m| m.latency_ns),
    ] {
        let shares = b.shares(select);
        let row: Vec<String> = shares
            .iter()
            .map(|(n, s)| format!("{n} {s:.1}%"))
            .collect();
        println!("  {metric:>8}: {}", row.join(" | "));
    }

    println!("\nmachine-readable report:");
    println!("{}", report.to_json().to_string_pretty());
    Ok(())
}
